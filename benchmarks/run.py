"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call empty for pure
accuracy/cost numbers; derived empty for pure timings) and writes a JSON
dump to experiments/bench_results.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: cost,convergence,training,"
                         "local_iters,kernels,roofline")
    ap.add_argument("--rounds", type=int, default=20)
    args = ap.parse_args()

    results = {}
    lines = []

    def report(name, us_per_call, derived):
        us = f"{us_per_call:.1f}" if us_per_call is not None else ""
        d = derived if derived is not None else ""
        line = f"{name},{us},{d}"
        lines.append(line)
        print(line, flush=True)

    print("name,us_per_call,derived", flush=True)

    sections = {
        "cost": lambda: __import__("benchmarks.paper_cost",
                                   fromlist=["run"]).run(report),
        "convergence": lambda: __import__("benchmarks.paper_convergence",
                                          fromlist=["run"]).run(report),
        "training": lambda: __import__(
            "benchmarks.paper_training",
            fromlist=["run"]).run(report, rounds=args.rounds),
        "local_iters": lambda: __import__(
            "benchmarks.paper_local_iters", fromlist=["run"]).run(report),
        "kernels": lambda: __import__("benchmarks.kernel_micro",
                                      fromlist=["run"]).run(report),
        "roofline": lambda: __import__("benchmarks.roofline_table",
                                       fromlist=["run"]).run(report),
    }
    chosen = (args.only.split(",") if args.only else list(sections))
    ok = True
    for name in chosen:
        try:
            results[name] = sections[name]()
        except Exception:
            ok = False
            traceback.print_exc()
            report(f"{name}/FAILED", None, "see stderr")

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.json", "w") as f:
        json.dump({k: v for k, v in results.items()
                   if not callable(v)}, f, indent=1, default=str)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
