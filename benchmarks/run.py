"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call empty for pure
accuracy/cost numbers; derived empty for pure timings) and writes a JSON
dump to experiments/bench_results.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

# allow `python benchmarks/run.py` as well as `python -m benchmarks.run`,
# with or without PYTHONPATH=src
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: cost,convergence,training,"
                         "local_iters,kernels,roofline,assoc_scale")
    ap.add_argument("--rounds", type=int, default=20)
    args = ap.parse_args()

    results = {}
    lines = []

    def report(name, us_per_call, derived):
        us = f"{us_per_call:.1f}" if us_per_call is not None else ""
        d = derived if derived is not None else ""
        line = f"{name},{us},{d}"
        lines.append(line)
        print(line, flush=True)

    print("name,us_per_call,derived", flush=True)

    sections = {
        "cost": lambda: __import__("benchmarks.paper_cost",
                                   fromlist=["run"]).run(report),
        "convergence": lambda: __import__("benchmarks.paper_convergence",
                                          fromlist=["run"]).run(report),
        "training": lambda: __import__(
            "benchmarks.paper_training",
            fromlist=["run"]).run(report, rounds=args.rounds),
        "local_iters": lambda: __import__(
            "benchmarks.paper_local_iters", fromlist=["run"]).run(report),
        "kernels": lambda: __import__("benchmarks.kernel_micro",
                                      fromlist=["run"]).run(report),
        "roofline": lambda: __import__("benchmarks.roofline_table",
                                       fromlist=["run"]).run(report),
        "assoc_scale": lambda: __import__("benchmarks.assoc_scale",
                                          fromlist=["run"]).run(report),
    }
    chosen = (args.only.split(",") if args.only else list(sections))
    ok = True
    for name in chosen:
        try:
            results[name] = sections[name]()
        except Exception:
            ok = False
            traceback.print_exc()
            report(f"{name}/FAILED", None, "see stderr")

    os.makedirs("experiments", exist_ok=True)
    out_path = "experiments/bench_results.json"
    fresh = {k: v for k, v in results.items() if not callable(v)}
    merged = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
        # rotate a baseline for scripts/bench_guard.py ONLY when this run
        # refreshed the guarded assoc_scale section — a cost-only or crashed
        # run must not destroy the guard's comparison point
        if "assoc_scale" in fresh:
            os.replace(out_path, "experiments/bench_results.prev.json")
    # accumulate sections across --only runs, but drop stale data for any
    # section that was chosen this run and FAILED — absence signals failure
    for name in chosen:
        if name not in fresh:
            merged.pop(name, None)
    merged.update(fresh)
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=1, default=str)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
