"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call empty for pure
accuracy/cost numbers; derived empty for pure timings) and writes a JSON
dump to experiments/bench_results.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

# allow `python benchmarks/run.py` as well as `python -m benchmarks.run`,
# with or without PYTHONPATH=src
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

# a fixed 4-device host mesh for the sharded assoc_scale section, matching
# scripts/tier1.sh (must land in the environment before jax first imports;
# a user-provided count wins)
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                               + os.environ.get("XLA_FLAGS", "")).strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: cost,convergence,training,"
                         "local_iters,kernels,roofline,assoc_scale,"
                         "live_hfel,admission")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: shrink the assoc_scale stress points "
                         "(skips the multi-minute N>=1000 runs) and swap "
                         "live_hfel's full three-policy run for a 2-round "
                         "verify-on smoke, so each section finishes in "
                         "under a minute; quick results are printed but NOT "
                         "persisted, so bench_guard baselines are never "
                         "disturbed")
    args = ap.parse_args()

    results = {}
    lines = []

    def report(name, us_per_call, derived):
        us = f"{us_per_call:.1f}" if us_per_call is not None else ""
        d = derived if derived is not None else ""
        line = f"{name},{us},{d}"
        lines.append(line)
        print(line, flush=True)

    print("name,us_per_call,derived", flush=True)

    sections = {
        "cost": lambda: __import__("benchmarks.paper_cost",
                                   fromlist=["run"]).run(report),
        "convergence": lambda: __import__("benchmarks.paper_convergence",
                                          fromlist=["run"]).run(report),
        "training": lambda: __import__(
            "benchmarks.paper_training",
            fromlist=["run"]).run(report, rounds=args.rounds),
        "local_iters": lambda: __import__(
            "benchmarks.paper_local_iters", fromlist=["run"]).run(report),
        "kernels": lambda: __import__("benchmarks.kernel_micro",
                                      fromlist=["run"]).run(report),
        "roofline": lambda: __import__("benchmarks.roofline_table",
                                       fromlist=["run"]).run(report),
        "assoc_scale": lambda: __import__(
            "benchmarks.assoc_scale",
            fromlist=["run"]).run(report, quick=args.quick),
        "live_hfel": lambda: __import__(
            "benchmarks.live_hfel",
            fromlist=["run"]).run(report, quick=args.quick),
        "admission": lambda: __import__(
            "benchmarks.admission",
            fromlist=["run"]).run(report, quick=args.quick),
    }
    chosen = (args.only.split(",") if args.only else list(sections))
    ok = True
    for name in chosen:
        try:
            results[name] = sections[name]()
        except Exception:
            ok = False
            traceback.print_exc()
            report(f"{name}/FAILED", None, "see stderr")

    if args.quick:
        print("quick mode: results not persisted", flush=True)
        if not ok:
            sys.exit(1)
        return

    def load_json(path):
        if not os.path.exists(path):
            return {}
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return {}

    os.makedirs("experiments", exist_ok=True)
    out_path = "experiments/bench_results.json"
    prev_path = "experiments/bench_results.prev.json"
    fresh = {k: v for k, v in results.items() if not callable(v)}
    merged = load_json(out_path)
    # rotate baselines for scripts/bench_guard.py PER SECTION: only sections
    # this run actually refreshed move their previous results into the
    # baseline file. A `--only` run therefore cannot rotate away unrelated
    # sections' baselines, and a crashed section keeps its comparison point.
    rotated = {name: merged[name] for name in fresh if name in merged}
    if rotated:
        prev = load_json(prev_path)
        prev.update(rotated)
        with open(prev_path, "w") as f:
            json.dump(prev, f, indent=1, default=str)
    # accumulate sections across --only runs, but drop stale data for any
    # section that was chosen this run and FAILED — absence signals failure
    for name in chosen:
        if name not in fresh:
            merged.pop(name, None)
    merged.update(fresh)
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=1, default=str)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
