"""Kernel microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-clock numbers measure the JAX reference paths (the kernels' TPU
performance is covered by the §Roofline analysis); the derived column
reports the max |kernel - oracle| error, which must stay tiny."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.resource_allocation import (SCREEN_PROFILES, solve_exact,
                                            solve_fixed_point,
                                            solve_fixed_point_batched)
from repro.core.cost_model import ra_constants
from repro.core.scenario import make_scenario
from repro.kernels import ops, ref


def _time(fn, *args, n=10):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _batched_consts(c, g, key):
    """Tile one server's (R,) RAConstants into a (G, R) batch with per-group
    jitter (same factor on f_min/f_max keeps the box ordered)."""
    scale = jax.random.uniform(key, (g, 1), minval=0.7, maxval=1.3)

    def bc(x):
        x = jnp.asarray(x)
        if x.ndim == 0:
            return jnp.broadcast_to(x, (g,))
        return x[None, :] * scale

    return jax.tree.map(bc, c)


def _golden_rows(report, timings):
    """Fused golden-section kernel vs the vmapped XLA fixed-point solver,
    across the three screening profiles and candidate-batch widths.

    On CPU the kernel runs in interpret mode, so its wall clock measures the
    interpreter, not Mosaic — the XLA row is the CPU production path and the
    derived maxerr column is the real payload (parity of the fused math)."""
    sc = make_scenario(64, 4, seed=0)
    c = ra_constants(sc.dev, sc.srv.bandwidth[0], sc.srv.noise[0], sc.lp)
    key = jax.random.key(7)
    for g in (64, 512, 4096):
        kb, km = jax.random.split(jax.random.fold_in(key, g))
        cg = _batched_consts(c, g, kb)
        masks = jax.random.uniform(km, (g, c.a.shape[0])) < 0.75
        masks = masks.at[:, 0].set(True)  # no empty groups
        for profile, iters in SCREEN_PROFILES.items():
            tag = f"{profile}_g{g}"
            xla = solve_fixed_point_batched(cg, masks, backend="xla", **iters)
            pal = solve_fixed_point_batched(cg, masks, backend="pallas",
                                            **iters)
            denom = jnp.maximum(jnp.abs(xla.cost), 1e-9)
            err = float(jnp.max(jnp.abs(pal.cost - xla.cost) / denom))
            us = _time(lambda cc=cg, m=masks, it=iters: jax.block_until_ready(
                solve_fixed_point_batched(cc, m, backend="xla", **it).cost))
            timings[f"golden_{tag}_xla_us"] = us
            report(f"kernel/golden_section/{tag}_xla_us", us,
                   f"maxrelerr={err:.2e}")
            us = _time(lambda cc=cg, m=masks, it=iters: jax.block_until_ready(
                solve_fixed_point_batched(cc, m, backend="pallas",
                                          **it).cost), n=3)
            timings[f"golden_{tag}_pallas_us"] = us
            report(f"kernel/golden_section/{tag}_pallas_us", us,
                   "interpret-mode")


def run(report):
    timings: dict[str, float] = {}
    rng = jax.random.key(0)
    ks = jax.random.split(rng, 8)

    q = jax.random.normal(ks[0], (2, 512, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 512, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 512, 4, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, True, 128, 128)
    err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(q, k, v))))
    us = _time(jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)),
               q, k, v)
    report("kernel/flash_attention/ref_us", us, f"maxerr={err:.2e}")

    x = jax.random.normal(ks[3], (4096, 1024))
    sc = jnp.ones((1024,))
    err = float(jnp.max(jnp.abs(ops.rmsnorm(x, sc) - ref.rmsnorm_ref(x, sc))))
    us = _time(jax.jit(ref.rmsnorm_ref), x, sc)
    report("kernel/rmsnorm/ref_us", us, f"maxerr={err:.2e}")

    u = jax.random.normal(ks[4], (32, 1 << 16))
    w = jax.random.uniform(ks[5], (32,)) + 0.1
    err = float(jnp.max(jnp.abs(ops.hier_aggregate(u, w)
                                - ref.hier_aggregate_ref(u, w))))
    us = _time(jax.jit(ref.hier_aggregate_ref), u, w)
    report("kernel/hier_aggregate/ref_us", us, f"maxerr={err:.2e}")

    states = jax.random.normal(ks[6], (16, 2, 8, 64, 32))
    decay = jax.random.uniform(ks[7], (16, 2, 8), minval=0.5, maxval=1.0)
    ent, fin = ops.ssd_state_scan(states, decay)
    ent_r, fin_r = ref.ssd_state_scan_ref(states, decay)
    err = max(float(jnp.max(jnp.abs(ent - ent_r))),
              float(jnp.max(jnp.abs(fin - fin_r))))
    us = _time(jax.jit(lambda s, d: ref.ssd_state_scan_ref(s, d)[1]),
               states, decay)
    report("kernel/ssd_state_scan/ref_us", us, f"maxerr={err:.2e}")

    # resource-allocation solver throughput (the scheduler's hot loop)
    sc2 = make_scenario(64, 4, seed=0)
    c = ra_constants(sc2.dev, sc2.srv.bandwidth[0], sc2.srv.noise[0], sc2.lp)
    mask = jnp.arange(64) < 48
    us = _time(lambda: jax.block_until_ready(solve_fixed_point(c, mask).cost))
    report("solver/fixed_point_us", us,
           f"cost={float(solve_fixed_point(c, mask).cost):.2f}")
    us = _time(lambda: jax.block_until_ready(solve_exact(c, mask).cost), n=3)
    report("solver/exact_us", us,
           f"cost={float(solve_exact(c, mask).cost):.2f}")

    _golden_rows(report, timings)
    return {"timings": timings}
