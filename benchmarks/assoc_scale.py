"""Association-engine scaling: device-resident fused-sweep engine
(repro.core.assoc_fast) vs the host-loop reference (run_batched).

Sections:
  * head-to-head at the paper's N=60/K=5 operating point — cold (includes
    jit compile) and warm wall-clock, plus the stable-point parity gap on a
    deterministic (exchange_samples=0) run;
  * large cluster-structured scenarios (make_large_scenario) that the host
    engine cannot reach in benchmark time, run end-to-end on the fast engine
    with screening profiles.

Timings land in the returned dict under "timings" so
``scripts/bench_guard.py`` can diff them against the previous run.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import make_scenario
from repro.core.assoc_fast import FastAssociationEngine
from repro.core.edge_association import AssociationEngine
from repro.core.scenario import make_large_scenario

# (n_devices, n_servers, profile, exchange_samples, max_moves)
# Per-round cost scales ~N^2 (a 2*(N+1)-group fused refresh of N-wide
# solves), so the stress points bound the number of steepest-descent moves:
# steepest descent applies the largest deltas first, so a bounded run still
# captures most of the attainable cost drop (reported as *_cost_drop).
SCALE_POINTS = [
    (250, 10, "coarse", 16, 80),
    (1000, 20, "coarse", 16, 40),
]


def run(report):
    t_start = time.time()
    timings: dict[str, float] = {}
    out: dict = {"timings": timings}

    # -- head to head at the paper's operating point ------------------------
    sc = make_scenario(60, 5, seed=0)
    t0 = time.time()
    ref = AssociationEngine(sc, kind="fast", seed=0).run_batched("random")
    t_ref = time.time() - t0
    timings["ref_run_batched_n60_k5"] = t_ref
    report("assoc_scale/ref_run_batched/N60_K5_s", None, round(t_ref, 3))

    # "default" = reference accuracy (strict parity); "coarse" = screening
    # accuracy for the headline sweep speedup (final costs are always
    # re-evaluated at reference accuracy, so relgap is a true quality gap).
    n60 = {"ref_cost": ref.total_cost, "ref_moves": ref.n_adjustments,
           "ref_seconds": t_ref}
    for profile in ("default", "coarse"):
        t0 = time.time()
        fast = FastAssociationEngine(sc, kind="fast", seed=0,
                                     profile=profile).run("random")
        t_cold = time.time() - t0
        t0 = time.time()
        fast = FastAssociationEngine(sc, kind="fast", seed=0,
                                     profile=profile).run("random")
        t_warm = time.time() - t0
        timings[f"fast_{profile}_cold_n60_k5"] = t_cold
        timings[f"fast_{profile}_warm_n60_k5"] = t_warm
        tag = f"N60_K5/{profile}"
        report(f"assoc_scale/fast_cold/{tag}_s", None, round(t_cold, 3))
        report(f"assoc_scale/fast_warm/{tag}_s", None, round(t_warm, 3))
        report(f"assoc_scale/speedup_warm/{tag}", None,
               round(t_ref / max(t_warm, 1e-9), 2))
        relgap = (fast.total_cost - ref.total_cost) / ref.total_cost
        report(f"assoc_scale/cost_relgap/{tag}", None, f"{relgap:+.2e}")
        n60[profile] = {"seconds_warm": t_warm, "cost": fast.total_cost,
                        "moves": fast.n_adjustments, "cost_relgap": relgap}
    out["n60"] = n60

    # deterministic parity gate (no exchanges -> both engines are
    # steepest-transfer-descent and must land on the same stable point)
    ref_d = AssociationEngine(sc, kind="fast", seed=0).run_batched(
        "nearest", exchange_samples=0)
    fast_d = FastAssociationEngine(sc, kind="fast", seed=0).run(
        "nearest", exchange_samples=0)
    parity = abs(ref_d.total_cost - fast_d.total_cost) / ref_d.total_cost
    report("assoc_scale/parity_rel_gap/N60_K5", None, f"{parity:.2e}")
    out["parity_rel_gap"] = parity

    # -- large-scenario end-to-end sweeps (fast engine only) ----------------
    scale = {}
    for n, k, profile, exchanges, max_moves in SCALE_POINTS:
        sc = make_large_scenario(n, k, seed=0)
        eng = FastAssociationEngine(sc, kind="fast", seed=0, profile=profile)
        t0 = time.time()
        res = eng.run("nearest", max_moves=max_moves,
                      exchange_samples=exchanges)
        dt = time.time() - t0
        tag = f"N{n}_K{k}"
        timings[f"fast_{tag.lower()}"] = dt
        report(f"assoc_scale/fast/{tag}_s", None, round(dt, 3))
        report(f"assoc_scale/fast/{tag}_moves", None, res.n_adjustments)
        report(f"assoc_scale/fast/{tag}_cost", None, round(res.total_cost, 2))
        # trace endpoints share the sweep profile, so the drop measures pure
        # descent improvement, free of cross-profile evaluation bias
        improved = (res.cost_trace[0] - res.cost_trace[-1]) / res.cost_trace[0]
        report(f"assoc_scale/fast/{tag}_cost_drop", None, round(improved, 4))
        scale[tag] = {"seconds": dt, "moves": res.n_adjustments,
                      "cost": res.total_cost, "cost_drop": improved}
    out["scale"] = scale

    report("assoc_scale/runtime_s", None, round(time.time() - t_start, 3))
    return out
