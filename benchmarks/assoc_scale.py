"""Association-engine scaling: device-resident fused-sweep engine
(repro.core.assoc_fast) vs the host-loop reference (run_batched), and
compacted reachable-set sweeps vs the dense fast engine.

Sections:
  * head-to-head at the paper's N=60/K=5 operating point — cold (includes
    jit compile) and warm wall-clock, plus the stable-point parity gap on a
    deterministic (exchange_samples=0) run;
  * compaction: per-move refresh cost of the dense (K, N) sweep vs the flat
    compacted (K, R) sweep vs the bucketed per-(K_b, R_b) adaptive-width
    sweep at N=1000/K=20 — all three are configurations of the ONE unified
    move-selection kernel — plus the padded-slot fraction each compaction
    wastes (the per-move figure subtracts a max_moves=0 init-only run from a
    bounded-move run, so jit-compile noise mostly cancels);
  * two-tier descent: coarse-to-stability + default polish vs a pure
    default-profile run at N=250/K=10 (cost parity at lower wall time);
  * churn: device-mobility re-convergence at N=1000/K=20 — one
    perturb_scenario tick (drift + reach flips + departures), then the
    incremental warm rerun (patched reach maps, stale-row-only toggle-cache
    refresh) vs a cold start on the perturbed scenario, with a hard
    bit-identical parity gate between the warm stable point and a cold
    rebuild from the same repaired assignment;
  * sharded: the shard_map sweep over the forced host-device mesh — hard
    bit-identical parity probes vs the classic single-device path (with
    sampled exchanges both off and on), an
    N=20k/K=200 cold wall-clock ratio, and the N=50k/K=500 headline (cold
    convergence to a stable point + one warm churn re-solve), the regime
    the PR's sharded candidate refresh exists for; timing keys carry the
    device count so bench_guard never compares across shard widths;
  * the N=2000/K=50 stress point run END-TO-END to a stable system point
    with the tiered compacted engine — the regime the dense engine cannot
    finish in benchmark time. This is a multi-minute run (~1s per coarse
    move at R~460, and convergence from the nearest init takes O(1000)
    moves); the dense projection at the measured per-move ratio would be
    hours, which is exactly what compaction unblocks.

``quick=True`` shrinks everything to a smoke subset (no host reference run,
no N>=1000 points) that finishes in under a minute; quick runs are not
persisted by benchmarks/run.py, so they never disturb bench_guard baselines.

Timings land in the returned dict under "timings" so
``scripts/bench_guard.py`` can diff them against the previous run.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import make_scenario
from repro.core.assoc_fast import FastAssociationEngine
from repro.core.edge_association import AssociationEngine
from repro.core.scenario import (make_large_scenario, perturb_scenario,
                                 reach_index_map)


def _head_to_head_n60(report, timings, quick):
    sc = make_scenario(60, 5, seed=0)
    n60: dict = {}
    t_ref = None
    if not quick:
        t0 = time.perf_counter()
        ref = AssociationEngine(sc, kind="fast", seed=0).run_batched("random")
        t_ref = time.perf_counter() - t0
        timings["ref_run_batched_n60_k5"] = t_ref
        report("assoc_scale/ref_run_batched/N60_K5_s", None, round(t_ref, 3))
        n60.update(ref_cost=ref.total_cost, ref_moves=ref.n_adjustments,
                   ref_seconds=t_ref)

    # "default" = reference accuracy (strict parity); "coarse" = screening
    # accuracy for the headline sweep speedup (final costs are always
    # re-evaluated at reference accuracy, so relgap is a true quality gap).
    for profile in ("default", "coarse"):
        t0 = time.perf_counter()
        fast = FastAssociationEngine(sc, kind="fast", seed=0,
                                     profile=profile).run("random")
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        fast = FastAssociationEngine(sc, kind="fast", seed=0,
                                     profile=profile).run("random")
        t_warm = time.perf_counter() - t0
        timings[f"fast_{profile}_cold_n60_k5"] = t_cold
        timings[f"fast_{profile}_warm_n60_k5"] = t_warm
        tag = f"N60_K5/{profile}"
        report(f"assoc_scale/fast_cold/{tag}_s", None, round(t_cold, 3))
        report(f"assoc_scale/fast_warm/{tag}_s", None, round(t_warm, 3))
        n60[profile] = {"seconds_warm": t_warm, "cost": fast.total_cost,
                        "moves": fast.n_adjustments}
        if not quick:
            report(f"assoc_scale/speedup_warm/{tag}", None,
                   round(t_ref / max(t_warm, 1e-9), 2))
            relgap = (fast.total_cost - n60["ref_cost"]) / n60["ref_cost"]
            report(f"assoc_scale/cost_relgap/{tag}", None, f"{relgap:+.2e}")
            n60[profile]["cost_relgap"] = relgap

    if quick:
        return n60, None
    # deterministic parity gate (no exchanges -> both engines are
    # steepest-transfer-descent and must land on the same stable point)
    ref_d = AssociationEngine(sc, kind="fast", seed=0).run_batched(
        "nearest", exchange_samples=0)
    fast_d = FastAssociationEngine(sc, kind="fast", seed=0).run(
        "nearest", exchange_samples=0)
    parity = abs(ref_d.total_cost - fast_d.total_cost) / ref_d.total_cost
    report("assoc_scale/parity_rel_gap/N60_K5", None, f"{parity:.2e}")
    return n60, parity


def _compaction(report, timings, n, k, max_moves):
    """Per-move refresh cost: dense (K, N) vs flat compacted (K, R) vs
    bucketed per-(K_b, R_b) sweeps of the one unified kernel.

    Each engine runs twice cold: an init-only (max_moves=0) fill and a
    bounded-move run; the difference divided by applied moves isolates the
    per-move refresh. The two programs share their loop-body HLO, so compile
    time largely cancels in the subtraction. The padded-slot fraction is the
    share of compacted slots that are pure padding — the wasted sweep work
    adaptive bucket widths exist to cut.
    """
    sc = make_large_scenario(n, k, seed=0)
    flat_reach = reach_index_map(sc.avail)
    bucketed_reach = reach_index_map(sc.avail, bucketed=True)
    r_max = flat_reach.r_max
    tag = f"N{n}_K{k}"
    report(f"assoc_scale/compaction/{tag}_r_max", None, r_max)
    report(f"assoc_scale/compaction/{tag}_padded_frac_flat", None,
           round(flat_reach.padded_fraction, 3))
    report(f"assoc_scale/compaction/{tag}_padded_frac_bucketed", None,
           round(bucketed_reach.padded_fraction, 3))
    out = {"r_max": r_max, "density": float(np.asarray(sc.avail).mean()),
           "padded_frac_flat": flat_reach.padded_fraction,
           "padded_frac_bucketed": bucketed_reach.padded_fraction,
           "bucket_widths": [b.width for b in bucketed_reach.buckets]}
    for compact, label in ((False, "dense"), (True, "compact"),
                           ("bucketed", "bucketed")):
        eng = FastAssociationEngine(sc, kind="fast", seed=0,
                                    profile="coarse", compact=compact)
        t0 = time.perf_counter()
        eng.run("nearest", max_moves=0, exchange_samples=0)
        t_init = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = eng.run("nearest", max_moves=max_moves, exchange_samples=0)
        t_total = time.perf_counter() - t0
        moves = max(res.n_adjustments, 1)
        per_move = (t_total - t_init) / moves
        timings[f"{label}_permove_{tag.lower()}"] = per_move
        report(f"assoc_scale/compaction/{tag}_{label}_permove_s", None,
               round(per_move, 3))
        out[label] = {"init_s": t_init, "total_s": t_total,
                      "moves": res.n_adjustments, "per_move_s": per_move,
                      "cost": res.total_cost}
    speedup = out["dense"]["per_move_s"] / max(out["compact"]["per_move_s"],
                                               1e-9)
    report(f"assoc_scale/compaction/{tag}_permove_speedup", None,
           round(speedup, 2))
    out["per_move_speedup"] = speedup
    b_speedup = out["compact"]["per_move_s"] / max(
        out["bucketed"]["per_move_s"], 1e-9)
    report(f"assoc_scale/compaction/{tag}_bucketed_vs_flat_permove", None,
           round(b_speedup, 2))
    out["bucketed_vs_flat_permove"] = b_speedup
    return out


def _two_tier(report, timings, n, k, max_moves, exchanges, rel_tol=1e-4):
    """Two-tier (coarse -> default polish) vs a pure default-profile run.

    Both sides stop at the same ``rel_tol`` so the cost gap and wall-time
    ratio measure tier quality, not tolerance differences (1e-4 bounds the
    long sub-threshold move tail that dominates large-N runs at 1e-5).
    """
    sc = make_large_scenario(n, k, seed=0)
    tag = f"N{n}_K{k}"
    # Both sides are timed WARM (each runs once untimed first): the two
    # sides share the default-profile XLA program, so whichever ran first
    # would pay its compile and hand the cache to the other for free —
    # timing cold would bias the wall ratio by run order.
    full_eng = FastAssociationEngine(sc, kind="fast", seed=0, rel_tol=rel_tol)
    full_eng.run("nearest", max_moves=max_moves, exchange_samples=exchanges)
    t0 = time.perf_counter()
    full = full_eng.run("nearest", max_moves=max_moves,
                        exchange_samples=exchanges)
    t_full = time.perf_counter() - t0
    eng = FastAssociationEngine(sc, kind="fast", seed=0, rel_tol=rel_tol)
    eng.run_tiered("nearest", tiers="two_tier", max_moves=max_moves,
                   exchange_samples=exchanges)
    t0 = time.perf_counter()
    tiered = eng.run_tiered("nearest", tiers="two_tier", max_moves=max_moves,
                            exchange_samples=exchanges)
    t_tier = time.perf_counter() - t0
    relgap = (tiered.total_cost - full.total_cost) / full.total_cost
    timings[f"default_only_{tag.lower()}"] = t_full
    timings[f"two_tier_{tag.lower()}"] = t_tier
    report(f"assoc_scale/two_tier/{tag}_default_only_s", None,
           round(t_full, 3))
    report(f"assoc_scale/two_tier/{tag}_tiered_s", None, round(t_tier, 3))
    report(f"assoc_scale/two_tier/{tag}_wall_ratio", None,
           round(t_tier / max(t_full, 1e-9), 3))
    report(f"assoc_scale/two_tier/{tag}_cost_relgap", None, f"{relgap:+.2e}")
    return {"default_only_s": t_full, "tiered_s": t_tier,
            "default_cost": full.total_cost, "tiered_cost": tiered.total_cost,
            "cost_relgap": relgap, "default_moves": full.n_adjustments,
            "tier_moves": eng.last_tier_moves}


def _stress(report, timings, n, k, max_moves, exchanges, rel_tol=1e-3):
    """Full-convergence stress run: tiered compacted engine to a stable
    system point at a declared epsilon-stability tolerance.

    ``rel_tol=1e-3`` bounds the improvement threshold below which a move no
    longer counts: from the nearest init the descent needs O(N) moves to
    reach it (~2000 at N=2000), and the sub-1e-3 tail alone would more than
    double the move count for a <0.5% further cost drop. Stability is still
    genuine — the run ends because NO candidate adjustment clears the
    threshold, not because it hit the move cap (the reported ``stable`` flag
    asserts exactly that).
    """
    sc = make_large_scenario(n, k, seed=0)
    tag = f"N{n}_K{k}"
    eng = FastAssociationEngine(sc, kind="fast", seed=0, rel_tol=rel_tol)
    init_assign = eng.initial_assignment("nearest")
    # evaluate the init point at reference accuracy, the scale _finalize
    # reports total_cost on — the tiered trace's endpoints are surrogates
    # from different screening profiles, so trace[0] vs trace[-1] would mix
    # ~1% of profile bias into the descent improvement
    init_cost = eng.evaluate_assignment(init_assign)
    t0 = time.perf_counter()
    res = eng.run_tiered("nearest", tiers="two_tier", max_moves=max_moves,
                         exchange_samples=exchanges, assignment=init_assign)
    dt = time.perf_counter() - t0
    stable = all(m < max_moves for m in eng.last_tier_moves)
    timings[f"stress_two_tier_{tag.lower()}"] = dt
    report(f"assoc_scale/stress/{tag}_s", None, round(dt, 3))
    report(f"assoc_scale/stress/{tag}_moves", None, res.n_adjustments)
    report(f"assoc_scale/stress/{tag}_cost", None, round(res.total_cost, 2))
    report(f"assoc_scale/stress/{tag}_stable", None, stable)
    improved = (init_cost - res.total_cost) / init_cost
    report(f"assoc_scale/stress/{tag}_cost_drop", None, round(improved, 4))
    return {"seconds": dt, "moves": res.n_adjustments,
            "tier_moves": eng.last_tier_moves, "cost": res.total_cost,
            "cost_drop": improved, "stable": stable, "rel_tol": rel_tol}


def _churn(report, timings, n, k, max_moves, rel_tol=1e-3):
    """Device-churn re-convergence: `rerun_incremental` (patched reach maps,
    stale-row-only cache refresh, warm start from the previous stable
    point) vs a cold start (fresh engine, full cache init, nearest-init
    descent) on the same perturbed scenario.

    Both timed sides may pay a one-off jit compile: the cold engine reuses
    the base run's program only when the perturbed scenario's bucket widths
    happen to match, and the warm side compiles the warm-init variant on
    its first call — so the wall ratio is an end-to-end single-tick figure,
    not a steady-state bound. The dominant term is move count either way
    (cold re-descends from the nearest init, warm from the repaired
    previous stable point). The parity gate at the end is the PR's
    acceptance criterion: the warm-started stable point must be
    bit-identical to a cold rebuild descending from the same repaired
    assignment.
    """
    sc = make_large_scenario(n, k, seed=0)
    tag = f"N{n}_K{k}"
    eng = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                rel_tol=rel_tol, compact="auto")
    t0 = time.perf_counter()
    base = eng.run("nearest", max_moves=max_moves, exchange_samples=0)
    t_base = time.perf_counter() - t0
    timings[f"churn_base_{tag.lower()}"] = t_base
    report(f"assoc_scale/churn/{tag}_base_s", None, round(t_base, 3))
    report(f"assoc_scale/churn/{tag}_base_moves", None, base.n_adjustments)

    # 5% of devices drift, 2% get a reach flip, 2% depart — a mild mobility
    # tick, the regime where re-solving from scratch is pure waste
    sc2, delta = perturb_scenario(sc, seed=1, drift_m=60.0, move_frac=0.05,
                                  flip_frac=0.02, depart_frac=0.02)
    report(f"assoc_scale/churn/{tag}_delta_devices", None,
           int(delta.touched_devices.sum()))
    report(f"assoc_scale/churn/{tag}_stale_servers", None,
           int(delta.stale_servers.sum()))

    cold_eng = FastAssociationEngine(sc2, kind="fast", seed=0,
                                     profile="coarse", rel_tol=rel_tol,
                                     compact=eng.compact)
    t0 = time.perf_counter()
    cold = cold_eng.run("nearest", max_moves=max_moves, exchange_samples=0)
    t_cold = time.perf_counter() - t0
    timings[f"churn_cold_{tag.lower()}"] = t_cold
    report(f"assoc_scale/churn/{tag}_cold_s", None, round(t_cold, 3))
    report(f"assoc_scale/churn/{tag}_cold_moves", None, cold.n_adjustments)

    t0 = time.perf_counter()
    warm = eng.rerun_incremental(sc2, delta, max_moves=max_moves,
                                 exchange_samples=0)
    t_warm = time.perf_counter() - t0
    timings[f"churn_warm_{tag.lower()}"] = t_warm
    report(f"assoc_scale/churn/{tag}_warm_s", None, round(t_warm, 3))
    report(f"assoc_scale/churn/{tag}_warm_moves", None, warm.n_adjustments)
    speedup = t_cold / max(t_warm, 1e-9)
    report(f"assoc_scale/churn/{tag}_wall_speedup", None, round(speedup, 2))
    report(f"assoc_scale/churn/{tag}_cost_relgap", None,
           f"{(warm.total_cost - cold.total_cost) / cold.total_cost:+.2e}")

    # hard parity gate (untimed): cold rebuild from the SAME repaired start
    parity = FastAssociationEngine(
        sc2, kind="fast", seed=0, profile="coarse", rel_tol=rel_tol,
        compact=eng.compact).run(assignment=eng.last_repaired_assignment,
                                 max_moves=max_moves, exchange_samples=0)
    assert np.array_equal(warm.assignment, parity.assignment), (
        "warm-started churn stable point diverged from the cold rebuild")
    assert warm.n_adjustments < cold.n_adjustments, (
        "incremental rerun must re-converge in fewer moves than cold start")
    report(f"assoc_scale/churn/{tag}_parity", None, True)
    return {"base_s": t_base, "base_moves": base.n_adjustments,
            "cold_s": t_cold, "cold_moves": cold.n_adjustments,
            "warm_s": t_warm, "warm_moves": warm.n_adjustments,
            "wall_speedup": speedup,
            "moves_ratio": cold.n_adjustments / max(warm.n_adjustments, 1),
            "touched_devices": int(delta.touched_devices.sum()),
            "stale_servers": int(delta.stale_servers.sum()),
            "compact": str(eng.compact), "rel_tol": rel_tol,
            "warm_cost": warm.total_cost, "cold_cost": cold.total_cost,
            "parity_ok": True}


def _sharded_scale(report, timings, quick):
    """Sharded-sweep scaling: the N=50k regime the single-device engine
    cannot reach in benchmark time.

    * hard parity probes (sharded vs classic stable point, bit-identical)
      at a small point, both transfer-only and with sampled exchanges on
      (PR 10's distributed proposal/winner-merge path) — quick mode stops
      here;
    * N=20k/K=200 smoke: cold sharded convergence plus the single-device
      cold run for the wall-clock ratio;
    * the N=50k/K=500 headline: cold sharded convergence END-TO-END to a
      stable point, then one churn tick re-solved warm via
      ``rerun_incremental`` — the elastic-reassociation operating mode
      ``fl/live.py`` needs at this scale. Both use ``finalize=False`` (no
      reference-accuracy re-evaluation of 500 groups) and ``spread_m=60``
      so per-server reach stays bounded as N grows.

    Every timing key carries the device count in ``device_counts`` so
    ``scripts/bench_guard.py`` refuses to compare runs made with different
    shard widths.
    """
    import jax

    p = min(4, len(jax.devices()))
    counts: dict[str, int] = {}
    out: dict = {"n_devices": p, "device_counts": counts}
    report("assoc_scale/sharded/devices", None, p)
    if p < 2:
        report("assoc_scale/sharded/SKIPPED", None,
               "single device — set XLA_FLAGS=--xla_force_host_platform"
               "_device_count=4")
        return out

    # hard parity probe: sharded stable point bit-identical to classic
    sc = make_large_scenario(250, 10, seed=0)
    ref = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                compact="bucketed").run(
        "nearest", max_moves=6, exchange_samples=0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                compact="bucketed", shards=p)
    t0 = time.perf_counter()
    res = eng.run("nearest", max_moves=6, exchange_samples=0)
    dt = time.perf_counter() - t0
    assert np.array_equal(ref.assignment, res.assignment), (
        "sharded stable point diverged from the classic sweep")
    timings["sharded_parity_n250_k10"] = dt
    counts["sharded_parity_n250_k10"] = p
    report("assoc_scale/sharded/N250_K10_parity", None, True)

    # PR 10: the same probe with sampled exchanges ON — the replicated pair
    # proposal + chunk-partitioned pricing + all_gather winner fold must
    # reproduce the classic exchange sequence bit-for-bit (the path the old
    # exchange_samples=0 restriction rejected outright)
    ref_ex = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                   compact="bucketed").run(
        "nearest", max_moves=6, exchange_samples=64)
    t0 = time.perf_counter()
    res_ex = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                   compact="bucketed", shards=p).run(
        "nearest", max_moves=6, exchange_samples=64)
    dt = time.perf_counter() - t0
    assert np.array_equal(ref_ex.assignment, res_ex.assignment), (
        "sharded sampled-exchange stable point diverged from the classic "
        "sweep")
    timings["sharded_exchange_parity_n250_k10"] = dt
    counts["sharded_exchange_parity_n250_k10"] = p
    report("assoc_scale/sharded/N250_K10_exchange_parity", None, True)
    if quick:
        return out

    def _cold(n, k, shards, tag, max_moves):
        eng = FastAssociationEngine(
            make_large_scenario(n, k, seed=0, spread_m=60.0), kind="fast",
            seed=0, profile="coarse", rel_tol=1e-2, compact="bucketed",
            shards=shards)
        t0 = time.perf_counter()
        eng.run("nearest", max_moves=max_moves, exchange_samples=0,
                finalize=False)
        dt = time.perf_counter() - t0
        stable = eng.last_moves < max_moves
        timings[tag] = dt
        counts[tag] = shards or 1
        report(f"assoc_scale/sharded/{tag}_s", None, round(dt, 3))
        report(f"assoc_scale/sharded/{tag}_moves", None, eng.last_moves)
        report(f"assoc_scale/sharded/{tag}_stable", None, stable)
        return eng, dt, stable

    # N=20k smoke: sharded vs single-device cold wall clock
    _, t_1dev, _ = _cold(20_000, 200, None, "sharded_cold_1dev_n20000_k200", 4000)
    _, t_pdev, _ = _cold(20_000, 200, p, f"sharded_cold_{p}dev_n20000_k200", 4000)
    speedup = t_1dev / max(t_pdev, 1e-9)
    report("assoc_scale/sharded/N20000_K200_wall_speedup", None,
           round(speedup, 2))
    out["smoke_n20000"] = {"cold_1dev_s": t_1dev, "cold_sharded_s": t_pdev,
                           "wall_speedup": speedup}

    # N=50k/K=500 headline: cold convergence + warm churn re-solve
    n, k = 50_000, 500
    sc_big = make_large_scenario(n, k, seed=0, spread_m=60.0)
    eng = FastAssociationEngine(sc_big, kind="fast", seed=0, profile="coarse",
                                rel_tol=1e-2, compact="bucketed", shards=p)
    tag = f"sharded_cold_{p}dev_n{n}_k{k}"
    t0 = time.perf_counter()
    eng.run("nearest", max_moves=8000, exchange_samples=0, finalize=False)
    t_cold = time.perf_counter() - t0
    stable = eng.last_moves < 8000
    timings[tag] = t_cold
    counts[tag] = p
    report(f"assoc_scale/sharded/{tag}_s", None, round(t_cold, 3))
    report(f"assoc_scale/sharded/{tag}_moves", None, eng.last_moves)
    report(f"assoc_scale/sharded/{tag}_stable", None, stable)
    assert stable, "N=50k headline run hit the move cap before stability"

    sc2, delta = perturb_scenario(sc_big, seed=1, drift_m=60.0,
                                  move_frac=0.01, depart_frac=0.005)
    wtag = f"sharded_warm_{p}dev_n{n}_k{k}"
    t0 = time.perf_counter()
    eng.rerun_incremental(sc2, delta, max_moves=8000, exchange_samples=0,
                          finalize=False)
    t_warm = time.perf_counter() - t0
    timings[wtag] = t_warm
    counts[wtag] = p
    report(f"assoc_scale/sharded/{wtag}_s", None, round(t_warm, 3))
    report(f"assoc_scale/sharded/{wtag}_moves", None, eng.last_moves)
    report(f"assoc_scale/sharded/{wtag}_wall_speedup", None,
           round(t_cold / max(t_warm, 1e-9), 2))
    out["headline_n50000"] = {
        "cold_s": t_cold, "warm_s": t_warm, "stable": stable,
        "warm_speedup_vs_cold": t_cold / max(t_warm, 1e-9),
        "touched_devices": int(delta.touched_devices.sum())}
    return out


def run(report, quick: bool = False):
    t_start = time.perf_counter()
    timings: dict[str, float] = {}
    out: dict = {"timings": timings, "quick": quick}

    out["n60"], parity = _head_to_head_n60(report, timings, quick)
    if parity is not None:
        out["parity_rel_gap"] = parity

    if quick:
        # smoke subset: one bounded compacted run and one bounded bucketed
        # run on a small large-scenario point, so the smoke mode exercises
        # both dispatch paths of the unified kernel (each is a single XLA
        # program, so compile cost stays in budget)
        sc = make_large_scenario(250, 10, seed=0)
        # explicit compact=True: "auto" now promotes this point to the
        # bucketed sweep (padded fraction > threshold), and the quick gate
        # below deliberately compares the FLAT sweep against the bucketed one
        eng = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                    compact=True)
        t0 = time.perf_counter()
        res = eng.run("nearest", max_moves=6, exchange_samples=0)
        dt = time.perf_counter() - t0
        timings["quick_compact_n250_k10"] = dt
        report("assoc_scale/quick/N250_K10_s", None, round(dt, 3))
        report("assoc_scale/quick/N250_K10_moves", None, res.n_adjustments)
        beng = FastAssociationEngine(sc, kind="fast", seed=0,
                                     profile="coarse", compact="bucketed")
        t0 = time.perf_counter()
        bres = beng.run("nearest", max_moves=6, exchange_samples=0)
        dt = time.perf_counter() - t0
        timings["quick_bucketed_n250_k10"] = dt
        report("assoc_scale/quick/N250_K10_bucketed_s", None, round(dt, 3))
        report("assoc_scale/quick/N250_K10_bucketed_moves", None,
               bres.n_adjustments)
        # hard parity gate: this is the only N=250-scale bucketed-vs-flat
        # probe (unit tests gate parity at N<=18), so a divergence must fail
        # the smoke run, not print an informational line
        assert np.array_equal(res.assignment, bres.assignment), (
            "bucketed quick point diverged from the flat compact sweep")
        # churn smoke: one incremental rerun with the verify gate ON, so
        # quick mode exercises the warm-init dispatch + parity end to end
        sc2, delta = perturb_scenario(sc, seed=1, drift_m=60.0,
                                      move_frac=0.05, depart_frac=0.02)
        t0 = time.perf_counter()
        wres = eng.rerun_incremental(sc2, delta, max_moves=6,
                                     exchange_samples=0, verify=True)
        dt = time.perf_counter() - t0
        timings["quick_churn_n250_k10"] = dt
        report("assoc_scale/quick/N250_K10_churn_s", None, round(dt, 3))
        report("assoc_scale/quick/N250_K10_churn_moves", None,
               wres.n_adjustments)
    else:
        out["compaction"] = {
            "N1000_K20": _compaction(report, timings, 1000, 20, max_moves=6)}
        # exchanges=0 keeps both comparisons deterministic: with sampling on,
        # the default-only and tiered runs draw different exchange sequences
        # and the cost gap would measure PRNG luck, not tier quality (the
        # exchange path itself is benchmarked in the N60 head-to-head and
        # exercised by tests/test_assoc_compact.py)
        out["two_tier"] = {
            "N250_K10": _two_tier(report, timings, 250, 10,
                                  max_moves=2000, exchanges=0)}
        out["stress"] = {
            "N2000_K50": _stress(report, timings, 2000, 50,
                                 max_moves=4000, exchanges=0)}
        out["churn"] = {
            "N1000_K20": _churn(report, timings, 1000, 20, max_moves=2000)}

    out["sharded"] = _sharded_scale(report, timings, quick)
    out["device_counts"] = out["sharded"].get("device_counts", {})

    report("assoc_scale/runtime_s", None, round(time.perf_counter() - t_start, 3))
    return out
