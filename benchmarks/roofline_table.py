"""Aggregate experiments/dryrun/*.json into the §Roofline table
(markdown, written to experiments/roofline_table.md)."""

from __future__ import annotations

import glob
import json
import os

HEADER = ("| arch | shape | mesh | mode | compute s | memory s | collective s "
          "| dominant | MODEL_FLOPS/HLO | roofline frac |")
SEP = "|" + "---|" * 10


def roofline_fraction(r) -> float:
    """Useful-compute time over the max roofline term: how close the step is
    to the binding roof. = (MODEL_FLOPS/chips/peak) / max(term)."""
    terms = [r["compute_s"], r["memory_s"], r["collective_s"]]
    binding = max(terms)
    if binding <= 0:
        return 0.0
    useful = r["compute_s"] * min(r.get("flops_ratio", 1.0), 1.0)
    return useful / binding


def build_table(dry_dir: str = "experiments/dryrun") -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        d = json.load(open(path))
        r = d["roofline"]
        frac = roofline_fraction(r)
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['mode']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['flops_ratio']:.3f} | {frac:.3f} |")
    return "\n".join([HEADER, SEP] + rows)


def run(report):
    table = build_table()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_table.md", "w") as f:
        f.write(table + "\n")
    n = table.count("\n") - 1
    report("roofline/cells_in_table", None, n)
    return table
