"""Paper Figs. 5-6: edge-association cost-reducing iteration counts under
growing device / server numbers (near-linear growth expected)."""

from __future__ import annotations

import time

from repro.core import make_scenario
from repro.core.edge_association import AssociationEngine


def run(report):
    t0 = time.perf_counter()
    iters_n = []
    for n in [15, 30, 45, 60]:
        sc = make_scenario(n, 5, seed=0)
        res = AssociationEngine(sc, kind="fast", seed=0).run_batched("random")
        iters_n.append(res.n_adjustments)
        report(f"fig5/adjustments/N{n}", None, res.n_adjustments)
    iters_k = []
    for k in [5, 15, 25]:
        sc = make_scenario(60, k, seed=0)
        res = AssociationEngine(sc, kind="fast", seed=0).run_batched("random")
        iters_k.append(res.n_adjustments)
        report(f"fig6/adjustments/K{k}", None, res.n_adjustments)
    report("paper_convergence/runtime_s", None, round(time.perf_counter() - t0, 3))
    return {"fig5": iters_n, "fig6": iters_k}
