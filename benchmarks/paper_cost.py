"""Paper Figs. 3-4: global cost ratio of HFEL vs the six §V.A benchmark
schemes, under growing device count (K=5 fixed) and growing server count
(N=60 fixed). The reported metric matches the paper: each scheme's global
cost normalized by the uniform-resource-allocation benchmark."""

from __future__ import annotations

import time

import numpy as np

from repro.core import make_scenario
from repro.core.edge_association import evaluate_scheme

SCHEMES = ["hfel", "comp_opt", "greedy", "random", "comm_opt", "uniform",
           "proportional"]


def cost_ratio_sweep(points, *, vary: str, fixed: int, seeds=(0, 1)):
    """Returns {scheme: [ratio per point]} with uniform == 1.0."""
    out = {s: [] for s in SCHEMES}
    for p in points:
        n, k = (p, fixed) if vary == "devices" else (fixed, p)
        totals = {s: [] for s in SCHEMES}
        for seed in seeds:
            sc = make_scenario(n, k, seed=seed)
            for s in SCHEMES:
                r = evaluate_scheme(sc, s, seed=seed)
                totals[s].append(r.total_cost)
        base = np.mean(totals["uniform"])
        for s in SCHEMES:
            out[s].append(float(np.mean(totals[s]) / base))
    return out


def run(report):
    t0 = time.perf_counter()
    fig3_points = [15, 30, 60]
    fig3 = cost_ratio_sweep(fig3_points, vary="devices", fixed=5, seeds=(0,))
    for i, p in enumerate(fig3_points):
        for s in SCHEMES:
            report(f"fig3/cost_ratio/{s}/N{p}", None, round(fig3[s][i], 4))

    fig4_points = [5, 15]
    fig4 = cost_ratio_sweep(fig4_points, vary="servers", fixed=60, seeds=(0,))
    for i, p in enumerate(fig4_points):
        for s in SCHEMES:
            report(f"fig4/cost_ratio/{s}/K{p}", None, round(fig4[s][i], 4))

    # headline claims (paper: HFEL reaches 37-58% of uniform; beats
    # comp/greedy/random/comm/proportional)
    hfel_mean = np.mean(fig3["hfel"])
    report("fig3/hfel_vs_uniform_mean", None, round(float(hfel_mean), 4))
    report("paper_cost/runtime_s", None, round(time.perf_counter() - t0, 3))
    return {"fig3": fig3, "fig4": fig4,
            "fig3_points": fig3_points, "fig4_points": fig4_points}
