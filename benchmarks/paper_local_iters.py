"""Paper Figs. 13-16: effect of local-iteration count L on convergence
(fixed edge iterations I=5) and cloud communication rounds to a target
accuracy under a fixed L*I budget."""

from __future__ import annotations

import time

from repro.data import make_mnist_like
from repro.fl import train_federated


def run(report, *, rounds: int = 15):
    t0 = time.perf_counter()
    ds = make_mnist_like(30, seed=0)
    out = {}

    # Figs. 13-14: growing L accelerates convergence per global round
    for local in [5, 10, 20, 50]:
        h = train_federated(ds, method="hfel", n_servers=5, rounds=rounds,
                            local_iters=local, edge_iters=5, lr=0.02,
                            eval_every=2)
        out[f"L{local}"] = h.test_acc
        report(f"fig13/test_acc_final/L{local}", None,
               round(h.test_acc[-1], 4))

    # Figs. 15-16: fixed L*I = 100; fewer local iters (more edge aggs)
    # need fewer cloud rounds to the target accuracy
    target = 0.85
    for local, edge in [(5, 20), (10, 10), (50, 2)]:
        h = train_federated(ds, method="hfel", n_servers=5, rounds=rounds,
                            local_iters=local, edge_iters=edge, lr=0.02,
                            eval_every=1)
        reached = next((i for i, a in enumerate(h.test_acc) if a >= target),
                       rounds)
        out[f"rounds_to_{target}_L{local}"] = reached
        report(f"fig15/cloud_rounds_to_{target}/L{local}", None, reached)
    report("paper_local_iters/runtime_s", None, round(time.perf_counter() - t0, 3))
    return out
