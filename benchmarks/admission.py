"""Streaming admission benchmark: the O(K)-per-device capacitated placement
path (`repro.core.greedy_admission`) that lets the live loop admit arrivals
WITHOUT waking the association solver.

Two regimes at N=20k / K=200 (the assoc_scale stress geometry, capacitated
with ``cap_slack=1.1``):

  * bulk admission — one ``greedy_admission`` call placing the whole
    population against empty servers, the cold-start cost of building the
    admitted view (devices/sec);
  * streaming admission — single-device calls against an already-loaded
    system, the per-arrival cost the live loop pays every round
    (admissions/sec). Each call is a fresh nearest-with-headroom argmin, so
    this is the honest per-arrival latency, not an amortized batch number.

Placements are asserted cap-feasible before any timing is reported — a
benchmark of an infeasible admission would be measuring a bug.

``quick=True`` shrinks to N=2000 / K=20 (results printed, not persisted).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import greedy_admission
from repro.core.scenario import make_large_scenario

#: single-arrival calls timed in the streaming regime
STREAM_CALLS = 2000


def _bench_geometry(report, timings, *, n, k, seed=0):
    sc = make_large_scenario(n, k, seed=seed, spread_m=60.0, cap_slack=1.1)
    cap = sc.capacity
    tag = f"N{n}_K{k}"
    dist, feas = sc.dist, sc.eff_avail
    devices = np.flatnonzero(sc.active_mask)

    # -- bulk: admit the whole population against empty servers
    load = np.zeros(k, dtype=np.int64)
    t0 = time.perf_counter()
    placed = greedy_admission(dist, feas, load, cap, devices)
    bulk_s = time.perf_counter() - t0
    assert (placed >= 0).all(), "bulk admission refused a device"
    assert (np.bincount(placed, minlength=k) <= cap).all()
    bulk_rate = devices.size / bulk_s
    report(f"admission/{tag}/bulk_admit_s", None, round(bulk_s, 4))
    report(f"admission/{tag}/bulk_devices_per_s", None, round(bulk_rate))
    timings[f"admission_bulk_{tag.lower()}"] = bulk_s

    # -- streaming: single arrivals against the loaded system. Evict a
    # deterministic sample to create headroom, then re-admit one at a time —
    # exactly the live loop's per-arrival call shape.
    rng = np.random.default_rng(seed)
    evicted = rng.choice(devices, size=min(STREAM_CALLS, devices.size),
                         replace=False)
    load = np.bincount(placed, minlength=k)
    np.subtract.at(load, placed[np.searchsorted(devices, evicted)], 1)
    t0 = time.perf_counter()
    got = 0
    for d in evicted:
        p = greedy_admission(dist, feas, load, cap, np.array([d]))
        got += int(p[0] >= 0)
    stream_s = time.perf_counter() - t0
    assert got == evicted.size, "streaming admission refused a re-arrival"
    assert (load <= cap).all()
    rate = evicted.size / stream_s
    report(f"admission/{tag}/stream_calls", None, int(evicted.size))
    report(f"admission/{tag}/admissions_per_s", None, round(rate))
    timings[f"admission_stream_{tag.lower()}"] = stream_s
    return {"n": n, "k": k, "cap_slack": 1.1,
            "bulk_s": bulk_s, "bulk_devices_per_s": bulk_rate,
            "stream_calls": int(evicted.size), "stream_s": stream_s,
            "admissions_per_s": rate}


def run(report, quick: bool = False):
    t_start = time.perf_counter()
    timings: dict[str, float] = {}
    out: dict = {"timings": timings, "quick": quick}
    if quick:
        out["N2000_K20"] = _bench_geometry(report, timings, n=2000, k=20)
    else:
        out["N20000_K200"] = _bench_geometry(report, timings, n=20_000,
                                             k=200)
    report("admission/runtime_s", None,
           round(time.perf_counter() - t_start, 3))
    return out
