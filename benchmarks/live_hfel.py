"""Live HFEL co-simulation benchmark (repro.fl.live): the three
re-association policies on ONE churning scenario trajectory.

The point under test is the ISSUE-5 acceptance criterion: on a churning
N=250/K=10 scenario,

  * ``incremental-warm`` and ``periodic-cold`` re-solve at the same swap
    points from the same repaired stable assignment, so their swap
    assignments are bit-identical and their cumulative eq.-(17) system
    costs match to rel <= 1e-6 (asserted here, not just reported);
  * ``incremental-warm`` spends measurably LESS association wall time than
    ``periodic-cold`` (it patches reach maps and re-solves only stale
    toggle-cache rows instead of rebuilding an engine per swap);
  * both re-association policies beat the frozen ``static`` assignment on
    cumulative cost (churn degrades a frozen association; that is the
    paper's premise for running association and training as one system).

Per-policy wall time and association-only time land in ``timings`` (all
keys carry "live", labelled expected-new by scripts/bench_guard.py on
their first comparison). Training hyper-parameters are deliberately small:
the training side only has to be *present* (hot-swaps, masking, arrivals
all exercised); its accuracy trend is tracked by the paper_training
benchmarks, not this one.

The full run also lands the PR-10 headline: one sharded N=50k/K=500
``incremental-warm`` trajectory WITH sampled exchanges (engine sharded over
the forced host-device mesh, exchange budget at the engine default of 64) —
the configuration the old ``exchange_samples=0`` sharding restriction made
illegal. Its timing keys carry the device count in ``device_counts`` so
``scripts/bench_guard.py`` never compares runs across shard widths.

``quick=True`` smokes ``run_live`` end-to-end in under a minute: 2 rounds
at N=40/K=4 with ``verify=True``, so the engine-level warm/cold parity
assertion runs INSIDE the smoke as well.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.scenario import make_large_scenario
from repro.data import make_mnist_like
from repro.fl import run_live
# the benchmark measures the library's own default per-round churn regime
from repro.fl.live import DEFAULT_CHURN as CHURN

POLICY_SLUGS = (("static", "static"), ("periodic-cold", "cold"),
                ("incremental-warm", "warm"))


def _run_policies(report, timings, *, n, k, rounds, resolve_every, seed=0):
    sc = make_large_scenario(n, k, seed=seed)
    ds = make_mnist_like(n, samples_total=3000, seed=seed)
    tag = f"N{n}_K{k}"
    out = {"n": n, "k": k, "rounds": rounds, "resolve_every": resolve_every,
           "churn": dict(CHURN)}
    hists = {}
    for policy, slug in POLICY_SLUGS:
        t0 = time.perf_counter()
        h = run_live(sc, ds, policy=policy, rounds=rounds,
                     resolve_every=resolve_every, churn=CHURN, seed=seed,
                     local_iters=2, edge_iters=2, lr=0.05, eval_every=rounds,
                     profile="coarse", rel_tol=1e-3)
        wall = time.perf_counter() - t0
        hists[policy] = h
        timings[f"live_total_{slug}_{tag.lower()}"] = wall
        timings[f"live_assoc_{slug}_{tag.lower()}"] = h.assoc_seconds_total
        report(f"live_hfel/{tag}/{slug}_total_s", None, round(wall, 3))
        report(f"live_hfel/{tag}/{slug}_assoc_s", None,
               round(h.assoc_seconds_total, 3))
        report(f"live_hfel/{tag}/{slug}_cum_cost", None,
               round(h.cumulative_cost, 2))
        report(f"live_hfel/{tag}/{slug}_moves", None, int(np.sum(h.moves)))
        out[slug] = {"total_s": wall,
                     "assoc_s": h.assoc_seconds_total,
                     "assoc_seconds": [float(s) for s in h.assoc_seconds],
                     "cumulative_cost": h.cumulative_cost,
                     "system_cost": [float(c) for c in h.system_cost],
                     "moves": [int(m) for m in h.moves],
                     "swap_rounds": [int(r) for r in h.swap_rounds],
                     "n_active": [int(a) for a in h.n_active],
                     "final_test_acc": float(h.train.test_acc[-1])}

    warm, cold, static = (hists["incremental-warm"], hists["periodic-cold"],
                          hists["static"])
    # -- acceptance gates (hard asserts: a silent miss must fail the run) --
    assert warm.swap_rounds == cold.swap_rounds, "swap schedules diverged"
    for r, aw, ac in zip(warm.swap_rounds, warm.swap_assignments,
                         cold.swap_assignments):
        assert np.array_equal(aw, ac), (
            f"warm/cold swap assignments diverged at round {r}")
    cost_rel = (abs(warm.cumulative_cost - cold.cumulative_cost)
                / cold.cumulative_cost)
    assert cost_rel <= 1e-6, f"warm/cold cumulative cost relgap {cost_rel:.2e}"
    assert warm.assoc_seconds_total < cold.assoc_seconds_total, (
        "incremental-warm must spend less association wall time than "
        "periodic-cold")
    assert warm.cumulative_cost <= static.cumulative_cost * (1 + 1e-9), (
        "incremental-warm must beat the static assignment on cumulative cost")
    assert cold.cumulative_cost <= static.cumulative_cost * (1 + 1e-9), (
        "periodic-cold must beat the static assignment on cumulative cost")

    assoc_speedup = cold.assoc_seconds_total / max(
        warm.assoc_seconds_total, 1e-9)
    static_gain = (static.cumulative_cost - warm.cumulative_cost) \
        / static.cumulative_cost
    report(f"live_hfel/{tag}/warm_cold_cost_relgap", None, f"{cost_rel:.2e}")
    report(f"live_hfel/{tag}/warm_vs_cold_assoc_speedup", None,
           round(assoc_speedup, 2))
    report(f"live_hfel/{tag}/reassoc_cost_gain_vs_static", None,
           f"{static_gain:+.4f}")
    report(f"live_hfel/{tag}/parity", None, True)
    out.update(warm_cold_cost_relgap=cost_rel, parity_ok=True,
               warm_vs_cold_assoc_speedup=assoc_speedup,
               reassoc_cost_gain_vs_static=static_gain)
    return out


def _run_sharded_live(report, timings, counts, *, n, k, rounds, seed=0):
    """The other half of the PR-10 ROADMAP item: a sharded live round at the
    N=50k/K=500 regime WITH sampled exchanges — the exact configuration the
    old ``exchange_samples=0`` sharding restriction forbade. One
    ``incremental-warm`` trajectory (round-0 cold solve + warm churn
    re-solves), engine sharded over every forced host device, exchange
    budget at the engine default. Bit-identical sharded-vs-classic parity
    is gated at small N by the test matrix and the assoc_scale probes;
    repeating it here would double a multi-minute run for no new signal.
    The 128-client bridge keeps the training side present but cheap —
    association cost at N=50k is what this section measures."""
    import jax

    p = len(jax.devices())
    tag = f"n{n}_k{k}"
    out: dict = {"n": n, "k": k, "rounds": rounds, "shards": p,
                 "exchange_samples": 64}
    report(f"live_hfel/sharded_{tag.upper()}/devices", None, p)
    if p < 2:
        report(f"live_hfel/sharded_{tag.upper()}/SKIPPED", None,
               "single device — set XLA_FLAGS=--xla_force_host_platform"
               "_device_count=4")
        return out
    sc = make_large_scenario(n, k, seed=seed, spread_m=60.0)
    ds = make_mnist_like(128, samples_total=2000, seed=seed)
    t0 = time.perf_counter()
    h = run_live(sc, ds, policy="incremental-warm", rounds=rounds,
                 resolve_every=1, churn=dict(drift_m=60.0, move_frac=0.01,
                                             flip_frac=0.005,
                                             depart_frac=0.005,
                                             arrive_frac=0.1),
                 seed=seed, local_iters=1, edge_iters=1, eval_every=rounds,
                 profile="coarse", rel_tol=1e-2, compact="bucketed",
                 shards=p, exchange_samples=64, max_moves=8000)
    wall = time.perf_counter() - t0
    timings[f"sharded_live_warm_{tag}"] = wall
    timings[f"sharded_live_assoc_{tag}"] = h.assoc_seconds_total
    counts[f"sharded_live_warm_{tag}"] = p
    counts[f"sharded_live_assoc_{tag}"] = p
    report(f"live_hfel/sharded_{tag.upper()}/total_s", None, round(wall, 3))
    report(f"live_hfel/sharded_{tag.upper()}/assoc_s", None,
           round(h.assoc_seconds_total, 3))
    report(f"live_hfel/sharded_{tag.upper()}/moves", None,
           int(np.sum(h.moves)))
    report(f"live_hfel/sharded_{tag.upper()}/cum_cost", None,
           round(h.cumulative_cost, 2))
    out.update(total_s=wall, assoc_s=h.assoc_seconds_total,
               assoc_seconds=[float(s) for s in h.assoc_seconds],
               moves=[int(m) for m in h.moves],
               cumulative_cost=h.cumulative_cost,
               n_active=[int(a) for a in h.n_active])
    return out


def run(report, quick: bool = False):
    t_start = time.perf_counter()
    timings: dict[str, float] = {}
    device_counts: dict[str, int] = {}
    out: dict = {"timings": timings, "device_counts": device_counts,
                 "quick": quick}

    if quick:
        # smoke: 2 rounds, warm policy, engine-level verify ON (each warm
        # re-solve is parity-checked against a cold rebuild inside)
        sc = make_large_scenario(40, 4, seed=0)
        ds = make_mnist_like(40, samples_total=800, seed=0)
        t0 = time.perf_counter()
        h = run_live(sc, ds, policy="incremental-warm", rounds=2,
                     resolve_every=1, churn=CHURN, seed=0, local_iters=1,
                     edge_iters=1, profile="coarse", rel_tol=1e-3,
                     verify=True)
        dt = time.perf_counter() - t0
        timings["live_quick_n40_k4"] = dt
        report("live_hfel/quick/N40_K4_s", None, round(dt, 3))
        report("live_hfel/quick/N40_K4_cum_cost", None,
               round(h.cumulative_cost, 2))
        report("live_hfel/quick/N40_K4_swaps", None, len(h.swap_rounds))
        assert sum(h.swapped) == 2 and h.rounds == 2
        out["quick_smoke"] = {"seconds": dt, "rounds": h.rounds,
                              "cumulative_cost": h.cumulative_cost}
    else:
        out["N250_K10"] = _run_policies(report, timings, n=250, k=10,
                                        rounds=8, resolve_every=2)
        out["sharded_N50000_K500"] = _run_sharded_live(
            report, timings, device_counts, n=50_000, k=500, rounds=2)

    report("live_hfel/runtime_s", None, round(time.perf_counter() - t_start, 3))
    return out
