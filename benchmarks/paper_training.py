"""Paper Figs. 7-12: HFEL vs FedAvg test/train accuracy and training loss
on MNIST-like and FEMNIST-like non-IID federated datasets (equal local
iteration budget per global round)."""

from __future__ import annotations

import time

from repro.core import make_scenario
from repro.core.edge_association import AssociationEngine
from repro.data import make_femnist_like, make_mnist_like
from repro.fl import train_federated


def run(report, *, rounds: int = 30):
    t0 = time.perf_counter()
    out = {}
    for name, maker in [("mnist", make_mnist_like),
                        ("femnist", make_femnist_like)]:
        ds = maker(30, seed=0)
        # HFEL's client->edge assignment comes from the core scheduler
        sc = make_scenario(30, 5, seed=0)
        assignment = AssociationEngine(sc, kind="fast",
                                       seed=0).run_batched("nearest").assignment
        h_hfel = train_federated(ds, method="hfel", assignment=assignment,
                                 n_servers=5, rounds=rounds, local_iters=10,
                                 edge_iters=5, lr=0.05, eval_every=5)
        h_fa = train_federated(ds, method="fedavg", rounds=rounds,
                               local_iters=10, edge_iters=5, lr=0.05,
                               eval_every=5)
        out[name] = {"hfel": h_hfel.as_dict(), "fedavg": h_fa.as_dict()}
        report(f"fig7_12/{name}/hfel/test_acc", None,
               round(h_hfel.test_acc[-1], 4))
        report(f"fig7_12/{name}/fedavg/test_acc", None,
               round(h_fa.test_acc[-1], 4))
        report(f"fig7_12/{name}/hfel/train_loss", None,
               round(h_hfel.train_loss[-1], 4))
        report(f"fig7_12/{name}/fedavg/train_loss", None,
               round(h_fa.train_loss[-1], 4))
        # mid-training gap (the paper's ~5% claim is about the transient)
        mid = len(h_hfel.test_acc) // 2
        report(f"fig7_12/{name}/acc_gap_mid", None,
               round(h_hfel.test_acc[mid] - h_fa.test_acc[mid], 4))
    report("paper_training/runtime_s", None, round(time.perf_counter() - t0, 3))
    return out
