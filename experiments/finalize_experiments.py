"""Append the generated §Roofline table and §Perf comparison to
EXPERIMENTS.md from the dry-run artifacts. Run once after the sweep and
hillclimbs complete:

    PYTHONPATH=src python experiments/finalize_experiments.py
"""

import glob
import json
import os
import sys

sys.path.insert(0, "src")
from benchmarks.roofline_table import build_table, roofline_fraction  # noqa: E402

DRY = "experiments/dryrun"


def load(tag, base=DRY):
    path = os.path.join(base, tag + ".json")
    return json.load(open(path)) if os.path.exists(path) else None


def fmt_cell(d):
    r = d["roofline"]
    amort = r.get("collective_s_amortized", r["collective_s"])
    return (f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
            f"x={amort:.3f}s dom={r['dominant']} "
            f"frac={roofline_fraction(r):.3f}")


def perf_rows():
    """(cell, variant, terms...) for the §Perf table — corrected-parser
    re-runs from experiments/perf/."""
    rows = []
    specs = [
        ("qwen2-7b__train_4k__single__sync__baseline",
         "baseline (fsdp, autodiff-attn)"),
        ("qwen2-7b__train_4k__single__sync__tp_only", "tp_only"),
        ("qwen2-7b__train_4k__single__sync__flash_vjp", "flash_vjp"),
        ("qwen3-0.6b__prefill_32k__single__sync__baseline",
         "baseline (fsdp, autodiff-attn)"),
        ("qwen3-0.6b__prefill_32k__single__sync__flash_vjp", "flash_vjp"),
        ("olmo-1b__train_4k__multi__sync__baseline",
         "baseline multi-pod (sync, probe-true)"),
        ("olmo-1b__train_4k__multi__hierarchical__hierarchical",
         "HFEL hierarchical (I=10, amortized)"),
    ]
    for tag, label in specs:
        d = load(tag, base="experiments/perf")
        if d is None:
            continue
        cell = f"{d['arch']} x {d['shape']} ({d['mesh']})"
        rows.append(f"| {cell} | {label} | {fmt_cell(d)} |")
    return rows


def main():
    n_json = len(glob.glob(os.path.join(DRY, "*.json")))
    n_err = len(glob.glob(os.path.join(DRY, "*.err")))
    table = build_table()
    with open("experiments/roofline_table.md", "w") as f:
        f.write(table + "\n")

    lines = [
        "",
        "---",
        "",
        "## Appendix A — §Dry-run summary (generated)",
        "",
        f"Compiled artifacts: {n_json} cells under `experiments/dryrun/` "
        f"({n_err} failures).",
        "",
        "## Appendix B — §Roofline table (generated, single-pod cells "
        "probe-extrapolated)",
        "",
        table,
        "",
        "## Appendix C — §Perf before/after (generated)",
        "",
        "| cell | variant | terms |",
        "|---|---|---|",
        *perf_rows(),
        "",
    ]
    with open("EXPERIMENTS.md", "a") as f:
        f.write("\n".join(lines))
    print(f"appended: {n_json} cells, {len(perf_rows())} perf rows")


if __name__ == "__main__":
    main()
