"""Serve a small LM with batched greedy decoding over a KV cache — the
serve_step path that the decode_32k / long_500k dry-run cells lower.

    PYTHONPATH=src python examples/serve_lm.py --batch 8 --new-tokens 64
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(vocab_size=512)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = jax.random.key(1)

    prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    max_len = args.prompt_len + args.new_tokens + 1
    batch = {"tokens": prompt}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    cache = model.decode_init(params, batch, max_len, dtype=jnp.float32)

    step = jax.jit(model.decode_step)

    # prefill by teacher-forcing the prompt through the decode path
    tok = prompt[:, 0]
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, t])
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    total = args.new_tokens * args.batch
    print(f"{args.arch} (reduced): {total} tokens in {dt:.2f}s "
          f"-> {total/dt:.1f} tok/s (batch={args.batch})")
    print("sample:", jnp.stack(out, axis=1)[0][:16].tolist())


if __name__ == "__main__":
    main()
