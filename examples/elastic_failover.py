"""Fault-tolerance demo: hierarchical FL training under node failures with
elastic edge re-association (Alg. 3 warm-started) and straggler dropping.

    PYTHONPATH=src python examples/elastic_failover.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import make_scenario
from repro.data import make_mnist_like
from repro.fl import train_federated
from repro.runtime import ElasticReassociator, FailureInjector

N, K = 20, 4

sc = make_scenario(N, K, seed=0)
er = ElasticReassociator(sc, seed=0)
initial = er.initial()
print(f"initial association cost {initial.total_cost:.1f} "
      f"({initial.n_adjustments} adjustments)")

ds = make_mnist_like(N, seed=0)
fi = FailureInjector(N, p_fail=0.08, p_recover=0.4, seed=3)
assignment_box = {"a": jnp.asarray(initial.assignment)}
events = []


def hook(trainer, r):
    alive = fi.step()
    trainer.client_mask = jnp.asarray(alive)
    if alive.sum() < N:   # membership changed -> re-associate live devices
        res = er.on_membership_change(alive)
        assignment_box["a"] = jnp.asarray(res.assignment)
        events.append((r, int(alive.sum()), res.n_adjustments,
                       round(res.total_cost, 1)))


hist = train_federated(ds, method="hfel",
                       assignment=np.asarray(initial.assignment),
                       n_servers=K, rounds=15, local_iters=10, edge_iters=5,
                       lr=0.05, eval_every=3, round_hook=hook)

print("\nfailure/re-association events (round, alive, adjustments, cost):")
for e in events[:10]:
    print(" ", e)
print(f"\nfinal test acc {hist.test_acc[-1]:.3f} "
      f"(training stayed sound through {len(events)} failure rounds)")
