"""End-to-end driver: train a ~100M-parameter LM with the HFEL hierarchical
sync schedule (Algorithm 1 at datacenter scale), checkpointing and restart.

Two "virtual pods" hold independent parameter copies; every step is an
edge-tier update (pod-local), every I-th step a cloud sync averages the
pods — exactly the paper's L/I structure. On a CPU container this runs a
scaled-down profile by default; pass --profile full for the 100M config.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import SyncLevel, SyncSchedule
from repro.data import TokenPipeline
from repro.models import build_model
from repro.optim import adamw, apply_updates, clip_by_global_norm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--profile", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--local-iters", type=int, default=5)
    ap.add_argument("--edge-iters", type=int, default=4)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/hfel_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    base = get_config("qwen3-0.6b")
    if args.profile == "full":     # ~100M params
        cfg = dataclasses.replace(base, n_layers=8, d_model=512, n_heads=8,
                                  n_kv_heads=4, head_dim=64, d_ff=2048,
                                  vocab_size=32_768, dtype="float32",
                                  max_seq_len=512)
        batch, seq = 8, 256
    else:
        cfg = base.reduced(n_layers=2, vocab_size=512)
        batch, seq = 4, 64
    model = build_model(cfg)
    print(f"config {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params "
          f"(profile={args.profile})")

    # one parameter copy per virtual pod (HFEL edge tier)
    params = [model.init(jax.random.key(p)) for p in range(args.pods)]
    opt = clip_by_global_norm(adamw(3e-3), 1.0)
    opt_states = [opt.init(p) for p in params]
    # all pods start from pod 0's weights (the paper broadcasts omega^0)
    params = [params[0]] * args.pods

    pipes = [TokenPipeline(cfg.vocab_size, seq, batch, seed=17 + p)
             for p in range(args.pods)]
    sched = SyncSchedule(args.local_iters, args.edge_iters)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    @jax.jit
    # hfellint: disable=HFEL006 -- pods alias one params pytree after init
    def train_step(params, opt_state, step, tokens):
        # (and after every cloud sync): donating pod p's buffers would
        # invalidate the other pods' step inputs
        loss, g = jax.value_and_grad(model.loss)(params, {"tokens": tokens})
        upd, opt_state = opt.update(g, opt_state, params, step)
        return apply_updates(params, upd), opt_state, loss

    start = 0
    if mgr.latest_step() is not None:
        s, restored, _ = mgr.restore(template={"params": params,
                                               "opt": opt_states})
        params, opt_states = restored["params"], restored["opt"]
        start = s
        print(f"resumed from checkpoint at step {start}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        losses = []
        for p in range(args.pods):
            tokens = jnp.asarray(next(pipes[p]))
            params[p], opt_states[p], loss = train_step(
                params[p], opt_states[p], step, tokens)
            losses.append(float(loss))
        if sched.level(step) == SyncLevel.CLOUD:
            mean = jax.tree.map(lambda *xs: sum(xs) / len(xs), *params)
            params = [mean] * args.pods
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_states})
        if step % 10 == 0 or step == args.steps - 1:
            lvl = sched.level(step).name
            print(f"step {step:4d} loss {sum(losses)/len(losses):.4f} "
                  f"sync={lvl} ({(time.perf_counter()-t0):.1f}s)")
    mgr.wait()
    print("done.")


if __name__ == "__main__":
    main()
