"""Capacity-constrained live HFEL: streaming admission under per-edge caps.

Every edge server carries a hard ``max_devices`` cap (``cap_slack`` sizes
caps off the nearest-server load profile). The live loop then splits the
population three ways each round:

  * admitted  — in the association view, training, counted against caps;
  * queued    — arrived (or displaced) devices no edge can admit yet; they
    wait in a bounded FIFO overflow queue, OUT of training;
  * rejected  — dropped off the queue's tail when it overflows
    ``overflow_max`` (they re-enter only by departing and re-arriving).

Admission is the O(K)-per-device ``greedy_admission`` path — a
nearest-with-headroom placement that never wakes the solver; the periodic
global re-solves (``resolve_every``) rebalance load and free headroom,
which the post-resolve admission tick immediately drains.

    PYTHONPATH=src python examples/streaming_admission.py
"""

import numpy as np

from repro.core import make_large_scenario
from repro.data import make_mnist_like
from repro.fl import run_live

N, K = 32, 4

# cap_slack=1.0 sizes each cap EXACTLY at the nearest-server count: zero
# global slack, so churn reliably pushes arrivals into the overflow queue
sc = make_large_scenario(N, K, seed=0, cap_slack=1.0)
print(f"per-edge caps {sc.capacity} (sum {sc.capacity.sum()}, N={N})")

ds = make_mnist_like(N, samples_total=800, seed=0)
churn = dict(drift_m=60.0, move_frac=0.2, flip_frac=0.1,
             depart_frac=0.2, arrive_frac=0.5)
h = run_live(sc, ds, policy="incremental-warm", rounds=8, resolve_every=2,
             churn=churn, seed=0, local_iters=2, edge_iters=2,
             overflow_max=16, verify=True)

print("\nround  active  queued  admitted  rejected  resolve  cost")
for r in range(h.rounds):
    print(f"{r:>5}  {h.n_active[r]:>6}  {h.n_queued[r]:>6}  "
          f"{h.n_admitted[r]:>8}  {h.n_rejected[r]:>8}  "
          f"{'yes' if h.swapped[r] else '':>7}  {h.system_cost[r]:>8.1f}")

print(f"\n{sum(h.n_admitted)} devices streamed in through the admission "
      f"path; {sum(h.n_rejected)} dropped from the overflow queue")
print(f"final test acc {h.train.test_acc[-1]:.3f} — training stayed sound "
      "while the admitted population floated under the caps")
assert sum(h.n_admitted) > 0 and h.rounds == 8
