"""Live HFEL co-simulation walkthrough: federated training while the device
population churns, with elastic edge re-association between cloud rounds.

    PYTHONPATH=src python examples/live_hfel.py

Three policies face the SAME churn trajectory (mobility drift, reach flips,
departures, arrivals — seeded per round):

  static            round-0 association frozen; only feasibility repair
  periodic-cold     re-solve from scratch every 2 rounds
  incremental-warm  FastAssociationEngine.rerun_incremental every 2 rounds
                    (patched reach maps, stale-row-only cache refresh)

incremental-warm and periodic-cold land on bit-identical assignments at
every swap (same repaired start, same descent) — the warm one just gets
there faster — and both undercut static on cumulative eq.-17 system cost.
"""

import numpy as np

from repro.core.scenario import make_large_scenario
from repro.data import make_mnist_like
from repro.fl import run_live

N, K, ROUNDS = 40, 4, 6
sc = make_large_scenario(N, K, seed=0)
ds = make_mnist_like(N, samples_total=800, seed=0)
churn = dict(drift_m=60.0, move_frac=0.1, flip_frac=0.05, depart_frac=0.08,
             arrive_frac=0.4)

hist = {}
for policy in ("static", "periodic-cold", "incremental-warm"):
    hist[policy] = run_live(sc, ds, policy=policy, rounds=ROUNDS,
                            resolve_every=2, churn=churn, seed=0,
                            local_iters=3, edge_iters=2, lr=0.05,
                            profile="coarse", rel_tol=1e-3)

warm = hist["incremental-warm"]
print(f"\nround-by-round ({warm.policy}):")
print("  r  active  swap  moves  assoc_s   eq17 cost")
for r in range(ROUNDS):
    print(f"  {r}  {warm.n_active[r]:>5}  {str(warm.swapped[r]):>5}"
          f"  {warm.moves[r]:>5}  {warm.assoc_seconds[r]:>7.2f}"
          f"  {warm.system_cost[r]:>10.2f}")

print("\npolicy comparison (same churn trajectory):")
print("  policy            cum eq17 cost   assoc s   final acc")
for name, h in hist.items():
    print(f"  {name:<17} {h.cumulative_cost:>13.2f}"
          f"  {h.assoc_seconds_total:>8.2f}"
          f"  {h.train.test_acc[-1]:>9.3f}")

cold = hist["periodic-cold"]
same = all(np.array_equal(a, b) for a, b in
           zip(warm.swap_assignments, cold.swap_assignments))
print(f"\nwarm/cold swap assignments bit-identical: {same}")
print("cumulative-cost gain over static: "
      f"{hist['static'].cumulative_cost - warm.cumulative_cost:+.2f}")
