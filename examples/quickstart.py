"""Quickstart: the paper's core loop in ~40 lines.

Builds a random HFEL scenario (Table II parameters), solves optimal
resource allocation per edge server (Section III), runs edge association to
a stable system point (Section IV), and prints the cost against the
benchmark schemes of §V.A.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import make_scenario
from repro.core.edge_association import AssociationEngine, evaluate_scheme

N_DEVICES, N_SERVERS = 20, 5

sc = make_scenario(N_DEVICES, N_SERVERS, seed=0)
print(f"scenario: {N_DEVICES} devices, {N_SERVERS} edge servers, "
      f"L(theta)={sc.lp.local_iters:.1f} local iters, "
      f"I(eps,theta)={sc.lp.edge_iters:.1f} edge iters")

engine = AssociationEngine(sc, kind="fast", seed=0)
res = engine.run_batched("random")
print(f"\nHFEL schedule: cost {res.cost_trace[0]:.1f} -> {res.total_cost:.1f} "
      f"after {res.n_adjustments} permitted adjustments (stable point)")
print("  assignment:", res.assignment.tolist())
print("  per-device CPU GHz:", np.round(res.f / 1e9, 2).tolist())
print("  per-device bandwidth share:", np.round(res.beta, 3).tolist())
print(f"  true eq.(17) cost: {res.true_cost:.1f} "
      f"(E={res.true_energy:.1f} J, T={res.true_delay:.1f} s)")

print("\nbenchmark schemes (global cost, lower is better):")
for scheme in ["hfel", "comp_opt", "greedy", "random", "comm_opt",
               "uniform", "proportional"]:
    r = evaluate_scheme(sc, scheme, seed=0)
    print(f"  {scheme:13s} {r.total_cost:12.1f}")
