# NOTE: repro.launch.dryrun must be imported/run in its own process (it sets
# XLA_FLAGS before jax init). Import submodules directly.
