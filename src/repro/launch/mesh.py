"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is the
HFEL "cloud" tier (DCN), ``data`` the "edge" tier (ICI), ``model`` tensor
parallelism.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — required for the dry-run's
XLA_FLAGS ordering.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU integration tests (requires host device count)."""
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def n_pods(mesh) -> int:
    return mesh.shape["pod"] if "pod" in mesh.axis_names else 1
