"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (DCN for the pod axis is modelled at 6.25 GB/s/host
separately in the analysis notes).

  compute term    = HLO_FLOPs / (chips * peak)
  memory term     = HLO_bytes / (chips * hbm_bw)
  collective term = collective_wire_bytes_per_chip / link_bw

cost_analysis() reports whole-program FLOPs/bytes (already per-partition
for SPMD modules). Collective bytes are parsed from the compiled HLO text:
for each collective op we take the result shape and apply ring-algorithm
wire formulas with the op's replica-group size.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, asdict

V5E = {
    "peak_flops": 197e12,     # bf16
    "hbm_bw": 819e9,          # bytes/s
    "ici_bw": 50e9,           # bytes/s per link
    "dcn_bw": 6.25e9,         # bytes/s per host (cross-pod)
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# result type is either one shape or a tuple; tuples may contain
# /*index=N*/ comments (which contain '='), so match balanced-paren-free
# content rather than "anything up to '='"
_COLL_RE = re.compile(
    r"=\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _iota_group_spread(n_groups: int, group_size: int, dims, perm):
    """Expand an iota replica-group spec and return the max (max-min) id
    spread across groups — the cross-pod classifier's input."""
    import numpy as np
    total = 1
    for d in dims:
        total *= d
    ids = np.arange(total).reshape(dims)
    if perm is not None:
        ids = ids.transpose(perm)
    flat = ids.reshape(n_groups, group_size)
    return int((flat.max(axis=1) - flat.min(axis=1)).max())


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0          # per participating device
    cross_pod_bytes: float = 0.0     # subset crossing the pod boundary
    counts: dict = None

    def __post_init__(self):
        if self.counts is None:
            self.counts = {}


def parse_collectives(hlo_text: str, *, pod_size: int = 256) -> CollectiveStats:
    stats = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        type_str, opcode, _start = m.group(1), m.group(2), m.group(3)
        result_bytes = _shape_bytes(type_str)
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end if line_end > 0 else None]

        g = _GROUPS_RE.search(line)
        gi = _GROUPS_IOTA_RE.search(line)
        if g:
            members = [int(x) for x in g.group(1).split(",") if x]
            n = max(len(members), 1)
            spread = (max(members) - min(members)) if members else 0
        elif gi:
            n_groups, n = int(gi.group(1)), int(gi.group(2))
            dims = [int(x) for x in gi.group(3).split(",")]
            perm = ([int(x) for x in gi.group(4).split(",")]
                    if gi.group(4) else None)
            spread = _iota_group_spread(n_groups, n, dims, perm)
        else:
            n, spread = 1, 0
        if n <= 1:
            continue

        if opcode == "all-reduce":
            wire = 2.0 * result_bytes * (n - 1) / n
        elif opcode == "all-gather":
            wire = result_bytes * (n - 1) / n
        elif opcode == "reduce-scatter":
            wire = result_bytes * (n - 1)
        elif opcode == "all-to-all":
            wire = result_bytes * (n - 1) / n
        else:  # collective-permute
            wire = float(result_bytes)

        stats.wire_bytes += wire
        if spread >= pod_size:
            stats.cross_pod_bytes += wire
        key = opcode
        stats.counts[key] = stats.counts.get(key, 0) + 1
    return stats


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    wire_bytes: float
    cross_pod_bytes: float
    dominant: str
    model_flops: float = 0.0
    flops_ratio: float = 0.0          # MODEL_FLOPS / HLO_FLOPs (global)
    collective_counts: dict = None

    def as_dict(self):
        return asdict(self)


def roofline_terms(cost_analysis: dict, collectives: CollectiveStats, *,
                   n_chips: int, per_partition: bool = True,
                   model_flops: float = 0.0, hw=V5E) -> RooflineTerms:
    """cost_analysis: compiled.cost_analysis(); flops/bytes accessed are
    per-partition for SPMD-compiled modules (XLA reports the partitioned
    program)."""
    flops = float(cost_analysis.get("flops", 0.0))
    raw_bytes = float(cost_analysis.get("bytes accessed", 0.0))
    # per-chip terms
    compute_s = flops / hw["peak_flops"]
    memory_s = raw_bytes / hw["hbm_bw"]
    coll_s = (collectives.wire_bytes - collectives.cross_pod_bytes) \
        / hw["ici_bw"] + collectives.cross_pod_bytes / hw["dcn_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    global_flops = flops * (n_chips if per_partition else 1)
    return RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        flops=flops, hbm_bytes=raw_bytes,
        wire_bytes=collectives.wire_bytes,
        cross_pod_bytes=collectives.cross_pod_bytes,
        dominant=dominant,
        model_flops=model_flops,
        flops_ratio=(model_flops / global_flops) if global_flops else 0.0,
        collective_counts=collectives.counts)
