import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis and roofline terms.

MUST be run as its own process (the XLA_FLAGS line above is read at first
jax initialization):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh single --mode sync --out experiments/dryrun

Two compiles per cell:
  * PROOF — the full config with scanned layers (compact HLO): this is the
    deliverable "lower+compile succeeds on the production mesh", and the
    source of memory_analysis().
  * PROBES — 1-unit and 2-unit deep UNROLLED configs: XLA cost analysis
    counts while-loop bodies exactly once, so the scanned program
    under-reports FLOPs/bytes/collectives by ~n_layers; unrolling the full
    depth is compile-prohibitive. Two shallow probes give the exact
    per-layer slope, extrapolated linearly to the full depth (layers are
    homogeneous, so the slope is exact modulo fusion edge effects).

Modes: ``sync`` (baseline full synchronization), ``hierarchical`` (HFEL
pod-local training; also lowers the per-I-steps cloud sync and reports its
amortized cost). Decode shapes lower ``serve_step`` instead of
``train_step``.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (CollectiveStats, parse_collectives,
                                   roofline_terms)
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import SHAPES, build_model, shape_applicable


def _train_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params; excludes the
    quadratic attention term, as is standard for the 6ND accounting)."""
    n_active = cfg.active_param_count()
    tokens = shape.seq_len * shape.global_batch
    return 6.0 * n_active * tokens


def _decode_flops_estimate(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    return 2.0 * n_active * shape.global_batch      # one token per sequence


def _probe_layer_counts(cfg):
    """(overrides_small, overrides_big, full_units) for the cost probes.

    The extrapolation unit is one homogeneous stack layer (hybrid: one
    period-group; encdec: one encoder + one decoder layer)."""
    if cfg.family == "hybrid" and cfg.hybrid_attn_period:
        p = cfg.hybrid_attn_period
        return {"n_layers": p}, {"n_layers": 2 * p}, cfg.n_layers // p
    if cfg.moe is not None and cfg.moe.n_dense_layers:
        nd = cfg.moe.n_dense_layers
        return ({"n_layers": nd + 1}, {"n_layers": nd + 2},
                cfg.n_layers - nd)
    if cfg.family == "encdec":
        return ({"n_layers": 1, "n_encoder_layers": 1},
                {"n_layers": 2, "n_encoder_layers": 2}, cfg.n_layers)
    return {"n_layers": 1}, {"n_layers": 2}, cfg.n_layers


def _lower_step(cfg, shape, mesh, mode, sharding_mode):
    model = build_model(cfg)
    if shape.kind == "decode":
        bundle = make_serve_step(model, mesh, shape,
                                 sharding_mode=sharding_mode)
        tok_spec = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        return bundle, bundle.step_fn.lower(bundle.params_spec,
                                            bundle.cache_spec, tok_spec)
    bundle = make_train_step(model, mesh, shape, mode=mode,
                             sharding_mode=sharding_mode)
    step_spec = jax.ShapeDtypeStruct((), jnp.int32)
    return bundle, bundle.step_fn.lower(bundle.params_spec, bundle.opt_spec,
                                        step_spec, bundle.batch_spec)


def _compile_costs(lowered) -> dict:
    compiled = lowered.compile()
    cost = dict(compiled.cost_analysis() or {})
    coll = parse_collectives(compiled.as_text(), pod_size=256)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.wire_bytes,
        "cross_pod": coll.cross_pod_bytes,
        "counts": coll.counts,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             mode: str = "sync", sharding_mode: str = "fsdp",
             edge_period: int = 10, probe: bool = True,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode, "sharding": sharding_mode,
    }

    # --- proof compile: the FULL config, scanned layers --------------------
    t0 = time.perf_counter()
    bundle, lowered = _lower_step(cfg, shape, mesh, mode, sharding_mode)
    result["lower_s"] = round(time.perf_counter() - t0, 1)
    t0 = time.perf_counter()
    compiled = lowered.compile()
    result["compile_s"] = round(time.perf_counter() - t0, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[attr] = int(v)
        result["per_device_bytes"] = int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))

    # --- cost probes --------------------------------------------------------
    if probe:
        ov1, ov2, full_units = _probe_layer_counts(cfg)
        t0 = time.perf_counter()
        c1 = _compile_costs(_lower_step(
            dataclasses.replace(cfg, scan_layers=False, **ov1),
            shape, mesh, mode, sharding_mode)[1])
        c2 = _compile_costs(_lower_step(
            dataclasses.replace(cfg, scan_layers=False, **ov2),
            shape, mesh, mode, sharding_mode)[1])
        result["probe_s"] = round(time.perf_counter() - t0, 1)

        def extrap(key):
            return max(c1[key] + (c2[key] - c1[key]) * (full_units - 1), 0.0)

        cost = {"flops": extrap("flops"), "bytes accessed": extrap("bytes")}
        coll = CollectiveStats(wire_bytes=extrap("wire"),
                               cross_pod_bytes=extrap("cross_pod"),
                               counts=c2["counts"])
        result["probe"] = {
            "full_units": full_units,
            "per_layer_flops": c2["flops"] - c1["flops"],
            "per_layer_wire_bytes": c2["wire"] - c1["wire"],
        }
    else:
        cost = dict(compiled.cost_analysis() or {})
        coll = parse_collectives(compiled.as_text(), pod_size=256)

    result["flops_per_partition"] = float(cost.get("flops", 0.0))
    result["bytes_per_partition"] = float(cost.get("bytes accessed", 0.0))

    model_flops = (_decode_flops_estimate(cfg, shape)
                   if shape.kind == "decode"
                   else _train_flops_estimate(cfg, shape))
    terms = roofline_terms(cost, coll, n_chips=n_chips,
                           model_flops=model_flops)
    result["roofline"] = terms.as_dict()

    # hierarchical mode: also lower + compile the cloud sync and amortize
    if mode == "hierarchical" and bundle.cloud_sync_fn is not None:
        sync_compiled = bundle.cloud_sync_fn.lower(
            bundle.params_spec, bundle.opt_spec).compile()
        sync_coll = parse_collectives(sync_compiled.as_text(), pod_size=256)
        sync_cost = dict(sync_compiled.cost_analysis() or {})
        sync_terms = roofline_terms(sync_cost, sync_coll, n_chips=n_chips)
        result["cloud_sync"] = sync_terms.as_dict()
        result["edge_period"] = edge_period
        result["roofline"]["collective_s_amortized"] = (
            terms.collective_s + sync_terms.collective_s / edge_period)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="architecture id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "hierarchical"])
    ap.add_argument("--sharding", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--edge-period", type=int, default=10)
    ap.add_argument("--no-probe", action="store_true",
                    help="skip cost probes (compile proof only)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if not shape_applicable(cfg, SHAPES[shape_name]):
                print(f"SKIP {arch} x {shape_name} (see DESIGN.md "
                      "§Arch-applicability)", flush=True)
                continue
            for multi_pod in meshes:
                mesh_tag = "multi" if multi_pod else "single"
                tag = f"{arch}__{shape_name}__{mesh_tag}__{args.mode}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"SKIP {tag} (exists)", flush=True)
                    continue
                try:
                    # probes drive the single-pod roofline table only
                    res = run_cell(arch, shape_name, multi_pod=multi_pod,
                                   mode=args.mode,
                                   sharding_mode=args.sharding,
                                   edge_period=args.edge_period,
                                   probe=not args.no_probe and not multi_pod)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    r = res["roofline"]
                    print(f"OK   {tag}: compile={res['compile_s']}s "
                          f"probe={res.get('probe_s', 0)}s "
                          f"dominant={r['dominant']} "
                          f"(c={r['compute_s']:.4f}s m={r['memory_s']:.4f}s "
                          f"x={r['collective_s']:.4f}s)", flush=True)
                except Exception as e:
                    failures += 1
                    with open(path + ".err", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
