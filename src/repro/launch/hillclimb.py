import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb runner: re-lowers a dry-run cell with one named
optimization applied and records the roofline delta vs. the baseline JSON.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --cell qwen2-7b:train_4k --opt flash_vjp --out experiments/dryrun

Optimizations (composable via comma):
  flash_vjp   — custom-VJP flash backward for blocked attention
                (replaces autodiff-through-scan; kills the O(tiles^2)
                carry traffic)
  tp_only     — sharding_mode="tp": drop FSDP parameter sharding over
                `data` (no per-layer param all-gathers; params replicated)
  full_sched  — attention schedule "full" (masked full computation; this is
                the DE-optimization used to quantify the triangle schedule)
  hierarchical— HFEL pod-local training on the multi-pod mesh (collective
                term reports the amortized cloud sync at --edge-period)
  no_remat    — disable activation rematerialization (memory for FLOPs)
"""

import argparse
import json

from repro.launch.dryrun import run_cell


def apply_opts(opts: list[str]):
    overrides = {}
    kwargs = {"mode": "sync", "sharding_mode": "fsdp", "multi_pod": False}
    for opt in opts:
        if opt == "flash_vjp":
            overrides["attn_vjp"] = "flash"
        elif opt == "tp_only":
            kwargs["sharding_mode"] = "tp"
        elif opt == "no_remat":
            overrides["remat"] = "none"
        elif opt == "hierarchical":
            kwargs["mode"] = "hierarchical"
            kwargs["multi_pod"] = True
        elif opt == "baseline":
            pass
        else:
            raise ValueError(opt)
    return overrides, kwargs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--opt", required=True,
                    help="comma list: flash_vjp,tp_only,hierarchical,"
                         "no_remat,baseline")
    ap.add_argument("--edge-period", type=int, default=10)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    arch, shape = args.cell.split(":")
    opts = args.opt.split(",")
    overrides, kwargs = apply_opts(opts)
    if args.multi_pod:
        kwargs["multi_pod"] = True

    res = run_cell(arch, shape, overrides=overrides,
                   edge_period=args.edge_period, probe=True, **kwargs)
    res["opts"] = opts
    mesh_tag = "multi" if kwargs["multi_pod"] else "single"
    tag = f"{arch}__{shape}__{mesh_tag}__{kwargs['mode']}__" + "-".join(opts)
    path = os.path.join(args.out, tag + ".json")
    os.makedirs(args.out, exist_ok=True)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    r = res["roofline"]
    print(f"{tag}: dominant={r['dominant']} compute={r['compute_s']:.4f}s "
          f"memory={r['memory_s']:.4f}s collective={r['collective_s']:.4f}s "
          f"(amortized={r.get('collective_s_amortized', r['collective_s']):.4f}s)",
          flush=True)


if __name__ == "__main__":
    main()
