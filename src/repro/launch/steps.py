"""pjit train/serve step builders.

Two training modes realize the paper's Algorithm 1 at datacenter scale:

* ``sync`` — conventional fully-synchronous data parallelism: one parameter
  copy, gradients all-reduced over every batch axis (pod + data). This is
  the flat-FedAvg analogue and the §Perf baseline.

* ``hierarchical`` (HFEL) — parameters carry a leading ``pod`` axis
  (one copy per pod, sharded P("pod", ...)): the train step only reduces
  gradients over the intra-pod ``data`` axis (ICI); the expensive DCN
  ``pod``-axis reduction happens once per I steps in
  :func:`make_cloud_sync_step` — eq. (8) every step, eq. (14) every I-th.
  Optionally the pod-sync payload goes through the compression operators.

Serving (``make_serve_step``) is one greedy decode step over a sharded KV /
state cache.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   param_pspec, param_shardings, _key_str)
from repro.models import pjit_hints
from repro.models.model import Model, ShapeSpec
from repro.optim import adamw, apply_updates, clip_by_global_norm



def _hier_param_shardings(params_spec, mesh, *, mode="fsdp"):
    """Shardings for pod-stacked parameters: P('pod', <per-param rules>)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    out = []
    for path, leaf in flat:
        inner = param_pspec(_key_str(path), leaf.shape[1:], mesh, mode=mode)
        out.append(NamedSharding(mesh, P("pod", *inner)))
    return jax.tree.unflatten(treedef, out)


@dataclass
class TrainStepBundle:
    step_fn: Any               # jitted train step
    cloud_sync_fn: Any | None  # jitted pod sync (hierarchical mode only)
    params_spec: Any           # ShapeDtypeStructs
    opt_spec: Any
    batch_spec: Any
    params_shardings: Any
    opt_shardings: Any
    batch_shardings: Any


def make_optimizer(lr: float = 3e-4, clip: float = 1.0):
    return clip_by_global_norm(adamw(lr), clip)


def make_train_step(model: Model, mesh, shape: ShapeSpec, *,
                    mode: str = "sync", sharding_mode: str = "fsdp",
                    lr: float = 3e-4, donate: bool = True,
                    compressor=None) -> TrainStepBundle:
    cfg = model.cfg
    opt = make_optimizer(lr)
    n_pods = mesh.shape.get("pod", 1)
    hierarchical = mode == "hierarchical"
    if hierarchical:
        assert n_pods > 1, "hierarchical mode needs a pod axis"

    params_spec = jax.eval_shape(model.init, jax.random.key(0))
    if hierarchical:
        params_spec = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((n_pods,) + l.shape, l.dtype),
            params_spec)
    opt_spec = jax.eval_shape(opt.init, params_spec)
    batch_spec = model.batch_specs(shape)

    p_shard = (_hier_param_shardings(params_spec, mesh, mode=sharding_mode)
               if hierarchical
               else param_shardings(params_spec, mesh, mode=sharding_mode))
    o_shard = param_shardings(opt_spec, mesh, mode=sharding_mode) \
        if not hierarchical else _hier_param_shardings(opt_spec, mesh,
                                                       mode=sharding_mode)
    b_shard = batch_shardings(batch_spec, mesh)

    if hierarchical:
        hints = pjit_hints.from_mesh(mesh, inside_pod_vmap=True)

        def loss_fn(params, batch):
            # split the global batch across pods; pair pod p's parameters
            # with pod p's sub-batch — vmapped with spmd_axis_name so the
            # mapped dim shards over 'pod' and no cross-pod reduction exists
            def reshape(leaf):
                return leaf.reshape((n_pods, leaf.shape[0] // n_pods)
                                    + leaf.shape[1:])

            pod_batch = jax.tree.map(reshape, batch)
            with pjit_hints.hints_ctx(hints):
                losses = jax.vmap(model.loss, spmd_axis_name="pod")(
                    params, pod_batch)
            return jnp.mean(losses)
    else:
        hints = pjit_hints.from_mesh(mesh)

        def loss_fn(params, batch):
            with pjit_hints.hints_ctx(hints):
                return model.loss(params, batch)

    def train_step(params, opt_state, step, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if hierarchical:
            updates, opt_state = jax.vmap(
                lambda g, s, p: opt.update(g, s, p, step)
            )(grads, opt_state, params)
        else:
            updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, step + 1, loss

    repl = NamedSharding(mesh, P())
    step_fn = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, repl, b_shard),
        out_shardings=(p_shard, o_shard, repl, repl),
        donate_argnums=(0, 1) if donate else ())

    cloud_sync_fn = None
    if hierarchical:
        def cloud_sync(params, opt_state):
            """eq. (14): average parameters (and moments) across pods."""
            def avg(leaf):
                if compressor is not None:
                    mean = jnp.mean(leaf, axis=0, keepdims=True)
                    delta = leaf - mean            # pod-local residual
                    delta, _ = compressor.compress(delta,
                                                   jnp.zeros_like(delta))
                    leaf = mean + delta
                m = jnp.mean(leaf, axis=0, keepdims=True)
                return jnp.broadcast_to(m, leaf.shape)

            return (jax.tree.map(avg, params),
                    jax.tree.map(avg, opt_state))

        cloud_sync_fn = jax.jit(
            cloud_sync,
            in_shardings=(p_shard, o_shard),
            out_shardings=(p_shard, o_shard),
            donate_argnums=(0, 1) if donate else ())

    return TrainStepBundle(step_fn, cloud_sync_fn, params_spec, opt_spec,
                           batch_spec, p_shard, o_shard, b_shard)


@dataclass
class ServeStepBundle:
    step_fn: Any
    params_spec: Any
    cache_spec: Any
    params_shardings: Any
    cache_shardings: Any
    token_sharding: Any


def make_serve_step(model: Model, mesh, shape: ShapeSpec, *,
                    sharding_mode: str = "fsdp",
                    donate: bool = True) -> ServeStepBundle:
    cfg = model.cfg
    params_spec = jax.eval_shape(model.init, jax.random.key(0))
    p_shard = param_shardings(params_spec, mesh, mode=sharding_mode)

    b = shape.global_batch
    if cfg.family == "encdec":
        frames_spec = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        cache_spec = jax.eval_shape(
            lambda p, f: model.decode_init(p, {"frames": f},
                                           shape.seq_len),
            params_spec, frames_spec)
    else:
        cache_spec, _ = model.decode_specs(shape)
    c_shard = cache_shardings(cache_spec, mesh)

    n_batch = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    tok_shard = NamedSharding(
        mesh, P(axes) if b % n_batch == 0 and b >= n_batch else P())

    hints = pjit_hints.from_mesh(mesh)

    def serve_step(params, cache, tokens):
        with pjit_hints.hints_ctx(hints):
            logits, cache = model.decode_step(params, cache, tokens)
            next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, cache

    step_fn = jax.jit(
        serve_step,
        in_shardings=(p_shard, c_shard, tok_shard),
        out_shardings=(tok_shard, c_shard),
        donate_argnums=(1,) if donate else ())

    return ServeStepBundle(step_fn, params_spec, cache_spec, p_shard,
                           c_shard, tok_shard)
