"""Production serving launcher: batched greedy decode over the sharded KV /
state cache (the serve_step the decode dry-run cells lower).

    python -m repro.launch.serve --arch qwen3-0.6b --new-tokens 32 \
        --devices 2x2 --reduced
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.steps import make_serve_step
from repro.models import SHAPES, ShapeSpec, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--devices", default=None, help="host mesh, e.g. 2x2")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    if args.devices:
        axes = tuple(int(x) for x in args.devices.split("x"))
        mesh = make_test_mesh(axes, ("data", "model"))
    else:
        mesh = make_production_mesh()
    shape = SHAPES[args.shape]
    if args.reduced:
        n_batch = mesh.shape.get("data", 1)
        shape = ShapeSpec(shape.name, seq_len=128,
                          global_batch=max(n_batch, 2), kind="decode")
    bundle = make_serve_step(model, mesh, shape)

    with mesh:
        params = jax.jit(model.init,
                         out_shardings=bundle.params_shardings)(
            jax.random.key(0))
        batch = {"tokens": jnp.zeros((shape.global_batch, 1), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (shape.global_batch, cfg.encoder_seq_len, cfg.d_model),
                jnp.float32 if args.reduced else jnp.bfloat16)
        cache = jax.device_put(
            model.decode_init(params, batch, shape.seq_len,
                              dtype=jnp.float32 if args.reduced
                              else jnp.bfloat16),
            bundle.cache_shardings)
        tok = jax.device_put(
            jnp.zeros((shape.global_batch,), jnp.int32),
            bundle.token_sharding)

        t0 = time.perf_counter()
        for _ in range(args.new_tokens):
            tok, cache = bundle.step_fn(params, cache, tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        total = args.new_tokens * shape.global_batch
        print(f"{args.arch}: {total} tokens in {dt:.2f}s "
              f"-> {total/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
