"""Production training launcher.

On a TPU slice this runs the pjit'd HFEL-hierarchical (or sync-baseline)
train step over the production mesh with checkpointing, retry, and the
paper's L/I sync schedule. On CPU it accepts a --devices override for a
small host mesh so the full path is exercisable in tests.

    python -m repro.launch.train --arch qwen3-0.6b --shape train_4k \
        --mode hierarchical --edge-period 10 --steps 100
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.steps import make_train_step
from repro.models import SHAPES, ShapeSpec, build_model
from repro.runtime import retry_with_backoff


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    if args.devices:
        shape_axes = [int(x) for x in args.devices.split("x")]
        if len(shape_axes) == 3:
            mesh = make_test_mesh(tuple(shape_axes),
                                  ("pod", "data", "model"))
        else:
            mesh = make_test_mesh(tuple(shape_axes), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.mode == "hierarchical")
    shape = SHAPES[args.shape]
    if args.reduced:
        n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                                if a != "model"]))
        shape = ShapeSpec(shape.name, seq_len=128,
                          global_batch=max(n_shards, 2), kind="train")
    bundle = make_train_step(model, mesh, shape, mode=args.mode, lr=args.lr)
    return cfg, model, mesh, shape, bundle


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "hierarchical"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--edge-period", type=int, default=10,
                    help="I: steps between cloud (pod) syncs")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--devices", default=None,
                    help="host test mesh, e.g. 2x2 or 2x2x1")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config/shape (CPU integration runs)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, model, mesh, shape, bundle = build(args)
    n_pods = mesh.shape.get("pod", 1)
    print(f"mesh {dict(mesh.shape)} | {args.arch} | mode={args.mode} "
          f"| batch {shape.global_batch} x seq {shape.seq_len}")

    with mesh:
        params = jax.jit(
            model.init, out_shardings=(
                bundle.params_shardings if args.mode != "hierarchical"
                else None))(jax.random.key(args.seed))
        if args.mode == "hierarchical":
            params = jax.device_put(
                jax.tree.map(
                    lambda p: jnp.broadcast_to(p, (n_pods,) + p.shape),
                    params),
                bundle.params_shardings)
        opt = make_opt_state(bundle, params)
        step = jnp.zeros((), jnp.int32)

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        pipe = TokenPipeline(cfg.vocab_size, shape.seq_len,
                             shape.global_batch, seed=args.seed)
        t0 = time.perf_counter()
        for k in range(args.steps):
            batch = {"tokens": jax.device_put(
                jnp.asarray(next(pipe)),
                bundle.batch_shardings["tokens"])}
            params, opt, step, loss = retry_with_backoff(
                lambda: bundle.step_fn(params, opt, step, batch))
            if args.mode == "hierarchical" and \
                    (k + 1) % args.edge_period == 0:
                params, opt = bundle.cloud_sync_fn(params, opt)
            if (k + 1) % args.ckpt_every == 0:
                mgr.save(k + 1, {"params": params})
            if k % 10 == 0 or k == args.steps - 1:
                print(f"step {k:5d} loss {float(loss):.4f} "
                      f"({time.perf_counter()-t0:.1f}s)", flush=True)
        mgr.wait()


def make_opt_state(bundle, params):
    from repro.launch.steps import make_optimizer
    opt = make_optimizer()
    return jax.jit(opt.init, out_shardings=bundle.opt_shardings)(params)


if __name__ == "__main__":
    main()
