"""GSPMD partition rules for the model zoo.

Rules map parameter path suffixes to logical roles and pick concrete
PartitionSpecs subject to divisibility by the mesh axis sizes (uneven dims
fall back to the next candidate or replication — e.g. whisper's 51866
vocab is not 16-divisible, so its embedding shards d_model instead).

Modes:
  * ``tp``   — tensor parallelism over ``model`` only; replicated over data.
  * ``fsdp`` — tp + the complementary large dim sharded over ``data``
               (ZeRO-3-style; GSPMD inserts the gather/scatter).

Stacked block parameters carry a leading layer axis which is never sharded.
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# (suffix regex, (model_dim_candidates, data_dim_candidates))
# dims are indices from the END of the shape (negative indexing), tried in
# order until one divides the axis size.
_RULES = [
    # embeddings: vocab over model ONLY — sharding D over data makes the
    # unembed contraction dim sharded, and GSPMD then all-gathers the full
    # batch of f32 logits (observed 40 GB/op). V-over-model keeps both the
    # embed gather and the logits einsum fully local.
    (r"embed/table$", ((-2, -1), ())),            # (V, D)
    (r"unembed/w$", ((-1, -2), ())),              # (D, V)
    (r"(wq|wk|wv|wi|wg)/w$", ((-1,), (-2,))),     # (D, F): F tp, D fsdp
    (r"wo/w$", ((-2,), (-1,))),                   # (F, D): F tp, D fsdp
    (r"wkv_a/w$", ((), (-2,))),                   # MLA down-proj (small)
    (r"wkv_b/w$", ((-1,), (-2,))),
    (r"router/w$", ((), (-2,))),
    (r"experts/.*?/w$", ((-3,), (-1,))),          # (E, a, b): experts -> EP
    (r"in_proj/w$", ((-1,), (-2,))),              # ssm
    (r"out_proj/w$", ((-2,), (-1,))),
    (r"conv_w$", ((-1,), ())),                    # (K, C): channels tp
    (r"pos_embed$", ((), (-2,))),
    (r"(a_log|d_skip|dt_bias|norm_scale|scale|bias|q_norm|k_norm|conv_b|/b)$",
     ((), ())),
]


def _key_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _pick(shape, candidates, axis_size, taken):
    for c in candidates:
        dim = len(shape) + c if c < 0 else c
        if 0 <= dim < len(shape) and dim not in taken \
                and shape[dim] % axis_size == 0 and shape[dim] >= axis_size:
            return dim
    return None


def param_pspec(path_str: str, shape, mesh, *, mode: str = "fsdp") -> P:
    if not shape:                       # scalars
        return P()
    model_size = mesh.shape["model"]
    data_size = mesh.shape["data"]
    spec = [None] * len(shape)
    for pattern, (model_cands, data_cands) in _RULES:
        if re.search(pattern, path_str):
            taken = set()
            dim = _pick(shape, model_cands, model_size, taken)
            if dim is not None:
                spec[dim] = "model"
                taken.add(dim)
            if mode == "fsdp":
                dim = _pick(shape, data_cands, data_size, taken)
                if dim is not None:
                    spec[dim] = "data"
            return P(*spec)
    # fallback heuristic: biggest divisible dim -> model, next -> data
    order = np.argsort(shape)[::-1]
    taken = set()
    for dim in order:
        dim = int(dim)
        if shape[dim] >= 1024 and shape[dim] % model_size == 0:
            spec[dim] = "model"
            taken.add(dim)
            break
    if mode == "fsdp":
        for dim in order:
            dim = int(dim)
            if dim not in taken and shape[dim] >= 1024 \
                    and shape[dim] % data_size == 0:
                spec[dim] = "data"
                break
    return P(*spec)


def param_shardings(params_spec, mesh, *, mode: str = "fsdp"):
    """Pytree of NamedSharding matching a params pytree (of arrays or
    ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_spec)
    out = []
    for path, leaf in flat:
        spec = param_pspec(_key_str(path), leaf.shape, mesh, mode=mode)
        out.append(NamedSharding(mesh, spec))
    return jax.tree.unflatten(treedef, out)


def batch_pspec(mesh) -> P:
    axes = ("pod", "data") if "pod" in mesh.axis_names else "data"
    return P(axes)


def batch_shardings(batch_spec, mesh, *, batch_divisible=True):
    """Shard every batch leaf on its leading (batch) dim when divisible."""
    n_batch_shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def one(leaf):
        if leaf.ndim and leaf.shape[0] % n_batch_shards == 0 \
                and leaf.shape[0] >= n_batch_shards:
            return NamedSharding(mesh, P(axes))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_spec)


def cache_shardings(cache_spec, mesh):
    """Decode-cache sharding: batch dim over (pod,)data when divisible,
    otherwise try a heads/state dim over model; else replicate.

    Cache leaves are stacked (L, B, ...) — dim 1 is batch."""
    n_batch = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    model_size = mesh.shape["model"]
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def one(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % n_batch == 0 \
                and leaf.shape[1] >= n_batch:
            spec[1] = axes
        # shard a trailing structured dim (kv heads / ssm heads / lora rank)
        for dim in range(leaf.ndim - 1, 1, -1):
            if leaf.shape[dim] % model_size == 0 \
                    and leaf.shape[dim] >= model_size:
                spec[dim] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_spec)
