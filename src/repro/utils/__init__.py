from repro.utils.trees import (
    tree_add,
    tree_scale,
    tree_sub,
    tree_weighted_mean,
    tree_zeros_like,
    tree_global_norm,
    tree_size,
    tree_cast,
)

__all__ = [
    "tree_add",
    "tree_scale",
    "tree_sub",
    "tree_weighted_mean",
    "tree_zeros_like",
    "tree_global_norm",
    "tree_size",
    "tree_cast",
]
