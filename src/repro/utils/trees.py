"""PyTree arithmetic helpers used across the FL runtime and optimizers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_weighted_mean(trees, weights):
    """Weighted mean of a list of pytrees: eq. (8)/(14) of the paper.

    ``weights`` is a 1-D array aligned with ``trees``; normalization is
    performed here so callers pass raw |D_n| sample counts.
    """
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)

    def combine(*leaves):
        stacked = jnp.stack(leaves)
        return jnp.tensordot(w.astype(stacked.dtype), stacked, axes=1)

    return jax.tree.map(combine, *trees)


def tree_global_norm(a):
    leaves = jax.tree.leaves(a)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_size(a):
    """Total number of scalar parameters in the pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(a))
