"""Two-tier (edge/cloud) aggregation primitives — paper Algorithm 1.

The same hierarchy is exposed at two scales:

* **Simulation scale** (FL runtime, CPU tests): lists of per-client pytrees
  aggregated with :func:`repro.utils.tree_weighted_mean` — eq. (8) at the
  edge, eq. (14) at the cloud.

* **Datacenter scale** (multi-pod mesh): `shard_map`-based collectives where
  the ``data`` mesh axis plays the edge tier (ICI) and the ``pod`` axis the
  cloud tier (DCN). :class:`SyncSchedule` decides, per step, whether to run
  a local step, an edge sync (psum over ``data``) or a cloud sync (psum over
  ``pod``) — the L(theta) / I(eps, theta) structure of Algorithm 1.
"""

from __future__ import annotations

from dataclasses import dataclass
from enum import IntEnum

import jax
import jax.numpy as jnp

from repro.utils import tree_weighted_mean


class SyncLevel(IntEnum):
    LOCAL = 0   # no cross-shard communication this step
    EDGE = 1    # aggregate within the pod (ICI, eq. 8)
    CLOUD = 2   # aggregate across pods (DCN, eq. 14)


@dataclass(frozen=True)
class SyncSchedule:
    """Algorithm 1's iteration structure.

    ``local_iters``  — L(theta): gradient steps between edge aggregations.
    ``edge_iters``   — I(eps, theta): edge aggregations between cloud syncs.

    Step indices are 1-based in the paper (t % L == 0 triggers aggregation);
    here ``level(step)`` takes the 0-based global step and returns what
    happens *after* that step's local update.
    """

    local_iters: int
    edge_iters: int

    def level(self, step: int) -> SyncLevel:
        s = step + 1
        if s % (self.local_iters * self.edge_iters) == 0:
            return SyncLevel.CLOUD
        if s % self.local_iters == 0:
            return SyncLevel.EDGE
        return SyncLevel.LOCAL

    def level_array(self, n_steps: int) -> jnp.ndarray:
        """Vectorized schedule for lax.scan-driven training loops."""
        s = jnp.arange(1, n_steps + 1)
        period = self.local_iters * self.edge_iters
        return jnp.where(s % period == 0, int(SyncLevel.CLOUD),
                         jnp.where(s % self.local_iters == 0,
                                   int(SyncLevel.EDGE), int(SyncLevel.LOCAL)))

    @property
    def cloud_period(self) -> int:
        return self.local_iters * self.edge_iters


# ---------------------------------------------------------------------------
# Simulation-scale aggregation (eqs. 8 and 14)
# ---------------------------------------------------------------------------

def edge_aggregate(client_models: list, client_samples) -> object:
    """omega_i = sum_n |D_n| omega_n / |D_{S_i}|  — eq. (8)."""
    return tree_weighted_mean(client_models, client_samples)


def cloud_aggregate(edge_models: list, edge_samples) -> object:
    """omega = sum_i |D_{S_i}| omega_i / |D|  — eq. (14)."""
    return tree_weighted_mean(edge_models, edge_samples)


# ---------------------------------------------------------------------------
# Datacenter-scale aggregation (inside shard_map)
# ---------------------------------------------------------------------------

def psum_mean(tree, axis_name: str, weight=None):
    """Weighted mean over a mesh axis: the shard_map realization of eq. (8)
    (axis 'data') and eq. (14) (axis 'pod'). Call inside shard_map."""
    if weight is None:
        n = jax.lax.psum(1.0, axis_name)
        return jax.tree.map(lambda x: jax.lax.psum(x, axis_name) / n, tree)
    total_w = jax.lax.psum(weight, axis_name)
    return jax.tree.map(
        lambda x: jax.lax.psum(x * weight, axis_name) / total_w, tree)


def hierarchical_sync(tree, level, *, edge_axis: str = "data",
                      cloud_axis: str = "pod", weight=None):
    """Apply the sync required by ``level`` (a traced int32 scalar).

    LOCAL: identity. EDGE: mean over ``edge_axis``. CLOUD: mean over
    ``edge_axis`` then ``cloud_axis`` (a cloud round always includes the
    final edge aggregation of Algorithm 1).

    Implemented with lax.switch so it can live inside a scanned train loop
    (the collective ops appear in all branches of the HLO; the branch select
    is data-dependent).
    """
    def local_fn(t):
        return t

    def edge_fn(t):
        return psum_mean(t, edge_axis, weight)

    def cloud_fn(t):
        t = psum_mean(t, edge_axis, weight)
        return psum_mean(t, cloud_axis)

    return jax.lax.switch(level, [local_fn, edge_fn, cloud_fn], tree)
