"""Device-resident edge association — ONE fused candidate-sweep kernel with
an incremental toggle-cost delta cache, parameterised by slot-index maps.

This is the performance engine behind Algorithm 3 / ``run_batched``: the whole
steepest-descent adjustment loop runs inside ONE jitted ``lax.while_loop``
with donated state buffers, so a full association run costs a single host
round-trip regardless of how many adjustments it applies. The reference
:class:`~repro.core.edge_association.AssociationEngine` instead drives every
round through Python loops, frozenset-keyed memo dicts, and one
``solve_batch`` host->device sync per candidate batch.

Unified slot-space design
-------------------------
Association state is a dense ``(K, N)`` boolean membership mask plus, per
*bucket* of servers, a compacted toggle-cost cache::

    toggle_b[row, r] = group cost of  member[server] XOR {device at slot r}
    cur[server]      = group cost of  member[server]

Because XOR adds a device when it is absent and removes it when present,
``toggle`` simultaneously caches every "group gains device" candidate (for
non-members) and every "group loses device" candidate (for members) — the
two halves of any transfer. The delta of moving device ``n`` from its server
``s = assign[n]`` to server ``k`` is then pure arithmetic::

    delta = (toggle[s at n's slot] - cur[s]) + (toggle[k at n's slot] - cur[k])

so each steepest-descent round scans ALL reachable transfer candidates with
zero solver calls, picks the best permitted move via ``lax`` reductions with
an explicit device-major tie-break key, and only then refreshes the cache. A
move touches exactly two servers, so the refresh solves each touched server's
current group plus its single-slot toggles — ``R_b + 1`` groups of vector
width ``R_b``, dispatched to the server's bucket with ``lax.switch``.

There is exactly one move-selection loop body (:func:`_run_device`); the
historical dense / compacted engines are *configurations* of it:

* **dense** (``compact=False``): one bucket whose index maps are the
  identity (``idx[k] = arange(N)``, every slot exists, candidate slots
  gated by ``avail``). The sweep then runs in the classic (K, N) space.
* **flat compact** (``compact=True``, auto-on for sparse reach): one bucket
  built from :func:`repro.core.scenario.reach_index_map` — all servers pad
  to the global max reach count R, and the per-move refresh solves
  ``R + 1`` groups of width R, an ``(N/R)^2``-ish cut versus dense that is
  what makes full N=2000/K=50 convergence runs tractable.
* **bucketed** (``compact="bucketed"``): adaptive slot widths.
  ``reach_index_map(avail, bucketed=True)`` groups servers into binary
  buckets by reach count (the same power-of-two scheme as
  ``GroupSolver.solve_batch``), each compacted at its own width ``R_b``, so
  one dense-reach server no longer pads every other server's row. The sweep
  evaluates one fused candidate scan per bucket and merges the per-bucket
  argmins with the same global device-major tie-break key, so move selection
  is order-identical to the flat configurations.

Padded slots carry garbage toggle costs by construction and are excluded
from every candidate mask; they never influence a move. The dense ``(K, N)``
mask stays the single source of truth: compacted membership rows are
gathered from it on demand (``member[servers[row], idx[row]] & exists``), so
applying a move is two dense column writes — no per-bucket scatter state to
keep consistent.

Sampled *exchanges* (Definition 5) ride the same fused sweep: when no
transfer is permitted, a ``lax.cond`` branch draws candidate device pairs
with the on-device PRNG, evaluates both swapped groups for every pair in ONE
vmapped solve in a shared all-server slot space (``ex_bucket``, flat width;
sampled pairs hit arbitrary server pairs, so pricing them once per width
bucket would multiply the solve work), and applies the best permitted swap
followed by the same two-row cache refresh in the per-bucket caches.
Swapped masks are built by XOR-ing one-hot slot encodings — an out-of-reach
slot encodes as the all-zero row, so unavailable swaps are naturally inert
and additionally gated.

Sharded sweep (``shards=p``)
----------------------------
For the N=50k+ regimes one device cannot price a sweep fast enough, the
same move-selection impl runs under ``shard_map`` over a ``p``-device mesh:
every bucket's rows (servers) are padded to a multiple of ``p`` and
partitioned along :data:`_SHARD_AXIS`, so each shard prices only its own
servers' candidate scans and R_b+1-group refreshes. Membership, assignment
and the (K, N) slot map stay replicated; per-shard (1, K) locator slices
mark foreign servers with a sentinel bucket id that dispatches to the
existing no-op refresh branch. Cross-shard consistency costs three
collectives per concern — ``psum`` over disjoint single-owner contributions
(bitwise exact: every other shard adds 0.0) for cache init / removal-toggle
gathers / post-move ``cur`` re-replication, and one ``all_gather`` +
lexicographic (delta, device-major order) fold that reproduces the
sequential bucket fold's move selection exactly. A sharded sweep therefore
applies the identical move sequence as the single-device program, and
``shards=None`` (the default) does not even trace the collectives — the
historical bit-exact graph is untouched. Sampled exchanges distribute too:
the pair *proposal* stays replicated — every shard splits the same key and
draws the identical ``(S, 2)`` batch, preserving the ``shards=None`` RNG
stream bit-for-bit — while the 2S candidate group-cost solves (the
expensive part) are index-partitioned across shards in contiguous sample
chunks, and the winning swap is selected by the same ``all_gather`` +
lexicographic (delta, sample-index order) fold the transfer path uses
(contiguous chunks make the per-shard argmin reproduce ``argmin``'s
first-occurrence tie-break globally). The apply step and the two-row cache
refresh then run exactly like a transfer's. On CPU, multi-device meshes
come from ``XLA_FLAGS=--xla_force_host_platform_device_count=<p>``.

``ra_backend="pallas"`` additionally routes every batched group solve of
the ``fast`` kind through the fused golden-section kernel
(:mod:`repro.kernels.golden_section`) instead of the vmapped op-by-op XLA
graph — one kernel call per R_b+1-group refresh. It matches the XLA solver
to float32 rounding (not bit-exactly), so the default stays ``"xla"``.

Two-tier descent (:meth:`FastAssociationEngine.run_tiered`)
-----------------------------------------------------------
Screening profiles trade solve accuracy for sweep speed but leave a ~1% cost
gap at the stable point. The tiered driver runs the adjustment loop once per
profile of a :data:`repro.core.resource_allocation.TIER_PLANS` plan (default
``"two_tier"`` = coarse then default), warm-starting each tier from the
previous tier's stable assignment. The coarse tier applies nearly all moves
cheaply; the default-accuracy polish then needs only a handful of moves to
recover the reference-accuracy stable point, at a fraction of a default-only
sweep's wall time. The concatenated ``cost_trace`` keeps each tier's
evaluation seam (tier boundaries re-evaluate the same assignment at the new
profile's accuracy, so the trace is monotone within tiers, not across them).

The per-group solver is :func:`repro.core.edge_association.solve_group`, so
every §V.A scheme kind works here; ``profile`` selects a
:data:`repro.core.resource_allocation.SCREEN_PROFILES` iteration preset
("default" reproduces the reference engine bit-for-bit on the solve level,
"screen"/"coarse" cut sweep cost ~2-4x for large-N scenarios).

Compilation: one XLA program per (bucket shape tuple, ``max_moves``,
``exchange_samples``, ``kind``, ``profile``, ``permission``,
``min_residual``). The jit cache is module-global, so repeated engines on
same-shaped scenarios reuse the compiled program.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import resource_allocation as ra
from repro.core.cost_model import cloud_delay, cloud_energy, global_cost
from repro.core.edge_association import (AssociationResult, GroupSolver,
                                         NoFeasibleServerError,
                                         greedy_admission, initial_assignment,
                                         nearest_feasible, parked_slots,
                                         solve_group)
from repro.core.scenario import (ReachBuckets, ReachIndex, Scenario,
                                 ScenarioDelta, reach_index_map,
                                 update_reach_buckets, update_reach_index)

_INF = jnp.inf
_I32_BIG = np.iinfo(np.int32).max

# Mesh axis name of the sharded sweep (see "Sharded sweep" in the module
# docstring): server-bucket rows are partitioned along it, everything else
# is replicated.
_SHARD_AXIS = "servers"

# ``compact="auto"`` promotes flat compaction to the bucketed adaptive-width
# sweep when the flat map wastes more than this fraction of its slots on
# padding. Measured (experiments/bench_results.json, assoc_scale/compaction):
# at padded fraction 0.353 (N=1000/K=20) bucketed sweeps are 1.63x faster
# per move than flat; near zero padding the per-bucket dispatch overhead
# wins nothing, so the threshold sits between the two regimes.
BUCKETED_AUTO_THRESHOLD = 0.25

#: The engine-wide default sampled-exchange budget (Definition 5 escape
#: moves per stuck round). ONE default everywhere — ``run``, ``run_tiered``,
#: ``rerun_incremental``, ``LiveHFELRunner``/``run_live`` — so no driver
#: silently drops the stochastic-escape path; pass ``exchange_samples=0``
#: explicitly for a deterministic transfer-only sweep.
DEFAULT_EXCHANGE_SAMPLES = 64


class _Bucket(NamedTuple):
    """One slot-width bucket of the unified sweep: the per-server index maps
    plus every RA constant pre-gathered into (K_b, R_b) slot space."""

    servers: jnp.ndarray    # (K_b,) global server ids
    idx: jnp.ndarray        # (K_b, R_b) device id per slot
    exists: jnp.ndarray     # (K_b, R_b) slot holds a real device
    ok: jnp.ndarray         # (K_b, R_b) slot is a legal transfer target
    consts: object          # RAConstants, leaves gathered per bucket row
    random_f: jnp.ndarray   # (K_b, R_b)
    inv_dist: jnp.ndarray   # (K_b, R_b)


def _bucket_cost_fn(kind, profile, bucket, cloud_const):
    """(bucket_row, slot_mask) -> group cost incl. the non-empty cloud
    constant of the row's server."""

    def cost(row, mask):
        c = jax.tree.map(lambda x: x[row], bucket.consts)
        sol = solve_group(kind, c, mask, random_f=bucket.random_f[row],
                          inv_dist_row=bucket.inv_dist[row], profile=profile)
        return sol.cost + jnp.where(jnp.any(mask),
                                    cloud_const[bucket.servers[row]], 0.0)

    return cost


def _bucket_costs_fn(kind, profile, bucket, cloud_const, ra_backend):
    """Batched ``(rows (M,), masks (M, R_b)) -> (M,) group costs`` for one
    bucket. ``ra_backend="xla"`` vmaps the scalar :func:`_bucket_cost_fn`
    (the historical, bit-exact path); ``"pallas"`` routes the ``fast`` kind
    through the fused golden-section kernel, solving the whole batch in one
    kernel call instead of a vmapped op-by-op graph."""
    if ra_backend == "pallas":
        iters = ra.SCREEN_PROFILES[profile]

        def costs(rows, masks):
            cb = jax.tree.map(lambda x: x[rows], bucket.consts)
            sol = ra.solve_fixed_point_batched(cb, masks, backend="pallas",
                                               **iters)
            return sol.cost + jnp.where(jnp.any(masks, axis=-1),
                                        cloud_const[bucket.servers[rows]],
                                        0.0)

        return costs
    return jax.vmap(_bucket_cost_fn(kind, profile, bucket, cloud_const))


@partial(jax.jit, donate_argnums=(0, 1),
         static_argnames=("kind", "profile", "permission", "min_residual",
                          "max_moves", "exchange_samples", "ra_backend"))
def _run_device(member, assignment, key, buckets, ex_bucket, slot_of,
                bucket_of, row_of, cloud_const, cap, rel_tol, warm=None, *,
                kind, profile, permission, min_residual, max_moves,
                exchange_samples, ra_backend="xla"):
    """The whole adjustment loop as one device program — the single
    move-selection kernel behind every sweep space (dense / flat compact /
    bucketed; see module docstring).

    ``buckets`` is a static-length tuple of :class:`_Bucket`; ``slot_of``
    (K, N) maps (server, device) to the device's slot in the server's bucket
    (out-of-range when unreachable), ``bucket_of``/``row_of`` (K,) locate
    each server's toggle row. ``ex_bucket`` is a single bucket covering ALL
    K servers (rows = server ids) in which exchange candidates are priced —
    sampled exchange pairs hit arbitrary server pairs, so evaluating them in
    one shared slot space avoids solving every pair once per width bucket.

    ``cap`` is the traced (K,) int32 per-edge admission capacity: a server
    at cap rejects inbound transfers (exchanges are 1-for-1, hence
    cap-neutral and never gated). The uncapacitated engine passes a cap of
    N everywhere — an inbound transfer needs a donor group elsewhere, so
    ``gsize < N`` always holds and the gate selects exactly the historical
    moves. Traced, not static: toggling caps never recompiles.

    ``warm`` is ``None`` (cold start: every cache row is solved at init) or
    ``(cur_prev (K,), toggles_prev per bucket, stale (K,) bool)`` — the
    incremental-rerun path: rows of non-stale servers are copied from the
    previous run's cache and only stale rows pay the R_b+1 group solves,
    which is what makes re-convergence under small scenario deltas cheap.

    Returns (member, assignment, cur, toggles, n_moves, trace); ``trace[i]``
    is the surrogate total after move i (trace[0] = initial total), padded
    with NaN past ``n_moves``.
    """
    return _run_device_impl(member, assignment, key, buckets, ex_bucket,
                            slot_of, bucket_of, row_of, cloud_const, cap,
                            rel_tol, warm, axis=None, kind=kind,
                            profile=profile, permission=permission,
                            min_residual=min_residual, max_moves=max_moves,
                            exchange_samples=exchange_samples,
                            ra_backend=ra_backend)


def _run_device_impl(member, assignment, key, buckets, ex_bucket, slot_of,
                     bucket_of, row_of, cloud_const, cap, rel_tol, warm, *,
                     axis, axis_size=1, kind, profile, permission,
                     min_residual, max_moves, exchange_samples, ra_backend):
    """Adjustment-loop body shared by the single-device jit
    (:func:`_run_device`, ``axis=None`` — traced graph identical to the
    historical kernel, so single-device results stay bit-exact) and the
    ``shard_map`` wrapper (:func:`_sharded_runner`, ``axis=_SHARD_AXIS``,
    ``axis_size`` = mesh size).

    Under sharding every bucket's rows are padded to a multiple of the mesh
    size and partitioned along axis 0; padded rows carry the sentinel server
    id K (scatters drop it, gathers clamp, ``exists``/``ok`` are False so it
    never becomes a candidate). ``bucket_of``/``row_of`` arrive as this
    shard's (1, K) locator slice whose sentinel bucket id ``len(buckets)``
    means "server owned by another shard" — it dispatches to the same no-op
    ``lax.switch`` branch that an unapplied move uses. Cross-shard state
    stays consistent through three collectives per concern: ``psum`` of
    disjoint single-owner contributions (cache init, removal-toggle gather,
    post-move ``cur`` re-replication — bitwise exact, every summand but one
    is 0.0) and an ``all_gather`` + lexicographic (delta, order) fold that
    reproduces the sequential bucket fold's device-major move selection
    exactly, so a sharded sweep applies the identical move sequence.

    Sampled exchanges distribute with the same split (module docstring,
    "Sharded sweep"): replicated pair proposal, sample-chunk-partitioned
    candidate pricing, all_gather + (delta, sample index) winner fold.
    """
    k, n = member.shape
    nb = len(buckets)
    i32 = jnp.int32
    idx_n = jnp.arange(n)
    # contiguous per-shard exchange-sample chunks: shard s prices global
    # samples [s*ex_chunk, (s+1)*ex_chunk); ceil-division padding samples
    # carry okay=False so they can never win
    ex_chunk = -(-exchange_samples // axis_size) if exchange_samples else 0
    ex_pad = ex_chunk * axis_size - exchange_samples
    if axis is not None:
        # this shard's locator slice: (1, K) -> (K,)
        bucket_of = bucket_of.reshape(-1)
        row_of = row_of.reshape(-1)

    def merge_sum(x):
        """Re-replicate disjoint single-owner contributions (every non-owner
        shard contributes exact 0.0, so the psum is bitwise the owner's
        value); identity on the single-device path."""
        return lax.psum(x, axis) if axis is not None else x

    cost_vs = [_bucket_costs_fn(kind, profile, bd, cloud_const, ra_backend)
               for bd in buckets]
    eyes = [jnp.eye(bd.idx.shape[1], dtype=bool) for bd in buckets]
    ex_cost_v = _bucket_costs_fn(kind, profile, ex_bucket, cloud_const,
                                 ra_backend)
    r_ex = ex_bucket.idx.shape[1]

    def base_rows(b, member, rows):
        """Compacted membership of bucket ``b``'s given rows, gathered from
        the dense mask (padded slots forced False)."""
        bd = buckets[b]
        return member[bd.servers[rows][:, None], bd.idx[rows]] & bd.exists[rows]

    def rows_costs(b, member, rows):
        """Solve each row's current group and all R_b single-slot toggles."""
        bd = buckets[b]
        rb = bd.idx.shape[1]
        base = base_rows(b, member, rows)                      # (m, rb)
        masks = jnp.concatenate(
            [base[:, None, :], base[:, None, :] ^ eyes[b][None]], axis=1)
        sids = jnp.repeat(rows, rb + 1)
        return cost_vs[b](sids, masks.reshape(-1, rb)).reshape(
            rows.shape[0], rb + 1)

    # ---- init: fill every bucket's toggle cache, one server at a time ----
    # (lax.map keeps peak memory at one server's (R_b+1, R_b) batch, which
    # is what allows N=2000-scale scenarios on a single host. On a warm
    # start the per-row cond skips the solves for rows the delta left
    # valid; the row still flows through the map so shapes never change.)
    cur0 = jnp.zeros(k, jnp.float32)
    toggles0 = []
    for b, bd in enumerate(buckets):
        kb = bd.idx.shape[0]
        if warm is None:
            def row_fn(rw, b=b):
                return rows_costs(b, member, rw[None])[0]
        else:
            cur_prev, toggles_prev, stale = warm

            def row_fn(rw, b=b):
                srv = buckets[b].servers[rw]
                kept = jnp.concatenate([cur_prev[srv][None],
                                        toggles_prev[b][rw]])
                return lax.cond(stale[srv],
                                lambda _: rows_costs(b, member, rw[None])[0],
                                lambda _: kept, None)
        costs = lax.map(row_fn, jnp.arange(kb, dtype=i32))     # (kb, rb+1)
        cur0 = cur0.at[bd.servers].set(costs[:, 0])
        toggles0.append(costs[:, 1:])
    toggles0 = tuple(toggles0)
    cur0 = merge_sum(cur0)

    trace0 = jnp.full(max_moves + 1, jnp.nan, cur0.dtype)
    trace0 = trace0.at[0].set(jnp.sum(cur0))

    def harmless(new, old):
        return new <= old + rel_tol * jnp.maximum(old, 1e-9)

    def removal_toggle(toggles, assign):
        """Per device: toggle cost of its current server losing it, gathered
        across buckets (each server's row lives in exactly one)."""
        sl = slot_of[assign, idx_n]                            # (n,)
        out = jnp.zeros(n, cur0.dtype)
        for b, bd in enumerate(buckets):
            kb, rb = bd.idx.shape
            v = toggles[b][jnp.clip(row_of[assign], 0, kb - 1),
                           jnp.clip(sl, 0, rb - 1)]
            out = jnp.where(bucket_of[assign] == b, v, out)
        return merge_sum(out)

    def can_join(srv, dev):
        """Availability gate for device(s) joining server(s), elementwise
        (ex_bucket rows are server ids, so no per-bucket dispatch needed)."""
        sl = slot_of[srv, dev]
        return (sl < r_ex) & ex_bucket.ok[srv, jnp.clip(sl, 0, r_ex - 1)]

    def refresh_server(member, server, applied, cur, toggles):
        """Refresh one touched server's cur + toggle row in its own bucket
        via lax.switch (extra branch = no-op when the move wasn't applied)."""

        def branch(b):
            def go(ops):
                cur, toggles = ops
                row = row_of[server]
                costs = rows_costs(b, member, row[None])       # (1, rb+1)
                return (cur.at[server].set(costs[0, 0]),
                        tuple(t.at[row].set(costs[0, 1:]) if i == b else t
                              for i, t in enumerate(toggles)))
            return go

        return lax.switch(jnp.where(applied, bucket_of[server], nb),
                          [branch(b) for b in range(nb)] + [lambda ops: ops],
                          (cur, toggles))

    def body(state):
        member, assign, cur, toggles, moves, key, trace, _ = state
        # -- scan all reachable transfer candidates from the cache (no
        #    solves), one fused scan per bucket, argmins merged globally --
        cur_src = cur[assign]                                  # (n,)
        minus = removal_toggle(toggles, assign)                # (n,)
        minus_delta = minus - cur_src
        gsize = jnp.sum(member, axis=1)                        # (k,)
        if permission == "pareto":
            src_harmless = harmless(minus, cur_src)            # (n,)

        best_delta = jnp.asarray(_INF, cur0.dtype)
        best_order = jnp.asarray(_I32_BIG, i32)
        t_dev = jnp.asarray(0, i32)
        t_dst = jnp.asarray(0, i32)
        for b, bd in enumerate(buckets):
            rb = bd.idx.shape[1]
            dev = bd.idx                                       # (kb, rb)
            cur_b = cur[bd.servers][:, None]                   # (kb, 1)
            src = assign[dev]                                  # (kb, rb)
            delta = minus_delta[dev] + toggles[b] - cur_b
            scale = jnp.maximum(cur_b + cur_src[dev], 1e-9)
            # capacity feasibility rides the same per-row mask as the
            # residual-group rule: a destination at cap admits no inbound
            # transfer (sentinel-padded rows are already ok=False, and the
            # clamped cap gather there is harmless)
            headroom = (gsize[bd.servers] < cap[bd.servers])[:, None]
            valid = (bd.ok & (src != bd.servers[:, None])
                     & (gsize[src] > min_residual) & headroom)
            permitted = valid & (delta < -rel_tol * scale)
            if permission == "pareto":
                permitted &= harmless(toggles[b], cur_b) & src_harmless[dev]
            masked = jnp.where(permitted, delta, _INF)
            bucket_best = jnp.min(masked)
            # explicit device-major order key reproduces the host reference
            # engine's argmin tie-breaking (smallest n*K + k among equal
            # deltas) — globally, across buckets
            order = dev.astype(i32) * k + bd.servers[:, None].astype(i32)
            tie = jnp.where(masked == bucket_best, order, _I32_BIG)
            p = jnp.argmin(tie)
            b_order = tie.reshape(-1)[p]
            take = ((bucket_best < best_delta)
                    | ((bucket_best == best_delta) & (b_order < best_order)))
            best_delta = jnp.where(take, bucket_best, best_delta)
            best_order = jnp.where(take, b_order, best_order)
            t_dev = jnp.where(take, dev.reshape(-1)[p], t_dev)
            t_dst = jnp.where(take, bd.servers[p // rb], t_dst)
        if axis is not None:
            # merge the per-shard winners with the SAME lexicographic
            # (delta, device-major order) rule the bucket fold above uses,
            # so the sharded sweep selects the identical global move
            deltas = lax.all_gather(best_delta, axis)          # (p,)
            orders = lax.all_gather(best_order, axis)
            g_delta = jnp.min(deltas)
            g_tie = jnp.where(deltas == g_delta, orders, _I32_BIG)
            shard = jnp.argmin(g_tie)
            best_delta = g_delta
            best_order = g_tie[shard]
            t_dev = lax.all_gather(t_dev, axis)[shard]
            t_dst = lax.all_gather(t_dst, axis)[shard]
        has_transfer = jnp.isfinite(best_delta)
        t_src = assign[t_dev]

        def do_transfer(args):
            member, assign, key = args
            m2 = member.at[t_src, t_dev].set(False).at[t_dst, t_dev].set(True)
            a2 = assign.at[t_dev].set(t_dst)
            return (jnp.asarray(True), jnp.stack([t_src, t_dst]), m2, a2, key)

        def no_exchange(args):
            member, assign, key = args
            return (jnp.asarray(False), jnp.zeros(2, i32), member, assign,
                    key)

        def do_exchange(args):
            member, assign, key = args
            # the pair PROPOSAL is replicated under sharding: every shard
            # splits the same key and draws the identical (S, 2) batch, so
            # the shards=None RNG stream is preserved bit-for-bit
            # hfellint: disable=HFEL007 -- replicated-key by design
            key, sub = jax.random.split(key)
            pairs = jax.random.randint(sub, (exchange_samples, 2), 0, n,
                                       dtype=i32)
            dn, dm = pairs[:, 0], pairs[:, 1]
            si, sj = assign[dn], assign[dm]
            okay = ((dn != dm) & (si != sj)
                    & can_join(sj, dn) & can_join(si, dm))

            def onehot(srv, dev):
                # an out-of-reach slot encodes as the all-zero row
                return jnp.arange(r_ex)[None, :] == slot_of[srv, dev][:, None]

            def ex_base(rows):
                return (member[ex_bucket.servers[rows][:, None],
                               ex_bucket.idx[rows]]
                        & ex_bucket.exists[rows])

            def price(dn_, dm_, si_, sj_, okay_):
                """Masked exchange deltas of a (sub)batch of sampled pairs —
                per-sample arithmetic identical on both paths, so chunked
                sharded pricing is bitwise the single-device pricing."""
                m = dn_.shape[0]
                gi = ex_base(si_) ^ onehot(si_, dn_) ^ onehot(si_, dm_)
                gj = ex_base(sj_) ^ onehot(sj_, dm_) ^ onehot(sj_, dn_)
                costs = ex_cost_v(jnp.concatenate([si_, sj_]),
                                  jnp.concatenate([gi, gj]))
                ci, cj = costs[:m], costs[m:]
                old = cur[si_] + cur[sj_]
                delta = ci + cj - old
                perm = okay_ & (delta < -rel_tol * jnp.maximum(old, 1e-9))
                if permission == "pareto":
                    perm &= harmless(ci, cur[si_]) & harmless(cj, cur[sj_])
                return jnp.where(perm, delta, _INF)

            if axis is None:
                masked = price(dn, dm, si, sj, okay)
                e = jnp.argmin(masked)
                best = masked[e]
            else:
                # this shard prices only its contiguous sample chunk; the
                # winner merge below is the transfer path's all_gather +
                # lexicographic (delta, order) fold with order = global
                # sample index, which reproduces the replicated argmin's
                # first-occurrence tie-break exactly
                start = lax.axis_index(axis) * ex_chunk

                def cut(x):
                    if ex_pad:
                        pad = jnp.zeros((ex_pad,) + x.shape[1:], x.dtype)
                        x = jnp.concatenate([x, pad])
                    return lax.dynamic_slice_in_dim(x, start, ex_chunk)

                masked = price(cut(dn), cut(dm), cut(si), cut(sj), cut(okay))
                el = jnp.argmin(masked)
                deltas = lax.all_gather(masked[el], axis)      # (p,)
                orders = lax.all_gather((start + el).astype(i32), axis)
                best = jnp.min(deltas)
                g_tie = jnp.where(deltas == best, orders, _I32_BIG)
                e = jnp.clip(g_tie[jnp.argmin(g_tie)], 0,
                             exchange_samples - 1)
            applied = jnp.isfinite(best)
            ri, rj = si[e], sj[e]
            dnb, dmb = dn[e], dm[e]
            m2 = member.at[ri, dnb].set(
                jnp.where(applied, False, member[ri, dnb]))
            m2 = m2.at[rj, dnb].set(jnp.where(applied, True, m2[rj, dnb]))
            m2 = m2.at[rj, dmb].set(jnp.where(applied, False, m2[rj, dmb]))
            m2 = m2.at[ri, dmb].set(jnp.where(applied, True, m2[ri, dmb]))
            a2 = assign.at[dnb].set(jnp.where(applied, rj, assign[dnb]))
            a2 = a2.at[dmb].set(jnp.where(applied, ri, a2[dmb]))
            return (applied, jnp.stack([ri, rj]), m2, a2, key)

        args = (member, assign, key)
        if exchange_samples:
            applied, rows, member, assign, key = lax.cond(
                has_transfer, do_transfer, do_exchange, args)
        else:
            applied, rows, member, assign, key = lax.cond(
                has_transfer, do_transfer, no_exchange, args)
        cur, toggles = refresh_server(member, rows[0], applied, cur, toggles)
        cur, toggles = refresh_server(member, rows[1], applied, cur, toggles)
        if axis is not None:
            # only the touched servers' owners re-solved their cur entries;
            # re-replicate exactly those two (psum of owner-only values)
            owned = bucket_of != nb
            touched = jnp.zeros(k, bool).at[rows].set(applied)
            fresh = merge_sum(jnp.where(touched & owned, cur, 0.0))
            cur = jnp.where(touched, fresh, cur)
        moves = moves + applied.astype(i32)
        trace = trace.at[moves].set(
            jnp.where(applied, jnp.sum(cur), trace[moves]))
        return (member, assign, cur, toggles, moves, key, trace, ~applied)

    def cond(state):
        return (~state[-1]) & (state[4] < max_moves)

    state = (member, assignment, cur0, toggles0, jnp.asarray(0, i32), key,
             trace0, jnp.asarray(False))
    member, assignment, cur, toggles, moves, _, trace, _ = lax.while_loop(
        cond, body, state)
    return member, assignment, cur, toggles, moves, trace


# jitted shard_map programs keyed on (mesh devices, bucket count, warm
# presence, statics) — module-global like _run_device's jit cache, so
# repeated engines on same-shaped scenarios reuse the compiled program
_SHARDED_CACHE: dict = {}


def _sharded_runner(mesh, n_buckets: int, has_warm: bool, *, kind, profile,
                    permission, min_residual, max_moves, exchange_samples,
                    ra_backend):
    """The sharded counterpart of :func:`_run_device`: the same impl wrapped
    in ``shard_map`` over ``mesh``. Bucket rows and the per-shard locator
    slices are partitioned along :data:`_SHARD_AXIS`; membership, assignment
    and all scalars are replicated, and the returned toggle caches reassemble
    into the global padded layout (so ``rerun_incremental`` warm-starts work
    unchanged across device counts). ``check_rep=False`` is required: jax
    has no replication rule for ``lax.while_loop`` bodies, and the impl's
    explicit psum/all_gather merges are what keep the replicated outputs
    consistent."""
    key = (tuple(mesh.devices.flat), n_buckets, has_warm, kind, profile,
           permission, min_residual, max_moves, exchange_samples, ra_backend)
    fn = _SHARDED_CACHE.get(key)
    if fn is None:
        body = partial(_run_device_impl, axis=_SHARD_AXIS,
                       axis_size=int(mesh.devices.size), kind=kind,
                       profile=profile, permission=permission,
                       min_residual=min_residual, max_moves=max_moves,
                       exchange_samples=exchange_samples,
                       ra_backend=ra_backend)
        shd, rep = P(_SHARD_AXIS), P()
        warm_spec = (rep, shd, rep) if has_warm else rep
        # (member, assignment, key, buckets, ex_bucket, slot_of, bucket_of,
        #  row_of, cloud_const, cap, rel_tol, warm)
        in_specs = (rep, rep, rep, shd, rep, rep, shd, shd, rep, rep, rep,
                    warm_spec)
        out_specs = (rep, rep, rep, shd, rep, rep)
        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False))
        _SHARDED_CACHE[key] = fn
    return fn


def _dense_member(assignment: np.ndarray, active: np.ndarray,
                  n_servers: int) -> np.ndarray:
    """Dense (K, N) membership of an assignment, gated by the active mask:
    inactive devices keep a parked bookkeeping slot in ``assignment`` but
    belong to no group (and cost nothing)."""
    member = np.zeros((n_servers, assignment.shape[0]), dtype=bool)
    act = np.asarray(active, dtype=bool)
    member[np.asarray(assignment)[act], np.flatnonzero(act)] = True
    return member


def _true_cost_terms(sc: Scenario, active: np.ndarray, assignment: np.ndarray,
                     f: np.ndarray, beta: np.ndarray
                     ) -> tuple[float, float, float]:
    """Eqs. (15)-(17) over the ACTIVE population only: inactive devices hold
    no resources and must not enter the per-device energy/delay terms. A
    fully-departed population has nothing training or transmitting, so its
    round costs (0, 0, 0) — a degenerate value, not an error, because churn
    can legitimately empty a small scenario mid-simulation and the live loop
    must record the round and keep going."""
    act = np.flatnonzero(np.asarray(active, dtype=bool))
    dev = sc.dev
    if act.size == 0:
        return 0.0, 0.0, 0.0
    if act.size < sc.n_devices:
        dev = jax.tree.map(lambda x: x[act], dev)
    e, t, c = global_cost(dev, sc.srv, jnp.asarray(np.asarray(assignment)[act]),
                          jnp.asarray(np.asarray(f)[act]),
                          jnp.asarray(np.maximum(np.asarray(beta)[act],
                                                 1e-9)), sc.lp)
    return float(e), float(t), float(c)


def assignment_true_cost(sc: Scenario, assignment: np.ndarray, *,
                         solver: GroupSolver | None = None,
                         kind: str = "fast", seed: int = 0
                         ) -> tuple[float, float, float]:
    """Paper eqs. (15)-(17) ``(energy, delay, cost)`` of an explicit
    assignment on ``sc`` at reference RA accuracy, gated by the scenario's
    active mask — the per-round system-cost accounting of the live
    co-simulation (:mod:`repro.fl.live`), usable without building a full
    association engine (the ``static`` policy never sweeps).

    ``solver`` may be a prebuilt default-profile :class:`GroupSolver` to
    amortize the RA-constants build across rounds: device/server physical
    parameters are churn-invariant (the :func:`perturb_scenario` contract),
    so one solver stays valid across mobility ticks for every scheme except
    ``proportional`` (whose inverse-distance draws follow ``sc.dist``; pass
    a fresh solver per tick for that kind).
    """
    if solver is None:
        solver = GroupSolver(sc, kind, seed=seed, profile="default")
    elif solver.kind != kind:
        raise ValueError(
            f"prebuilt solver was built for kind={solver.kind!r}, "
            f"not {kind!r}")
    else:
        # the documented contract is reference accuracy: a screening-profile
        # solver (e.g. an engine's own coarse sweep solver) is viewed at the
        # default profile — with_profile shares constants, so this is free.
        # (``seed`` only matters when building; a prebuilt solver keeps its
        # own random_f draws for the fixed-f scheme kinds.)
        solver = solver.with_profile("default")
    assignment = np.asarray(assignment)
    active = sc.active_mask
    member = _dense_member(assignment, active, sc.n_servers)
    sols = solver.solve_batch(np.arange(sc.n_servers), member)
    jm = jnp.asarray(member)
    f = np.asarray(jnp.sum(jnp.where(jm, sols.f, 0.0), axis=0))
    beta = np.asarray(jnp.sum(jnp.where(jm, sols.beta, 0.0), axis=0))
    return _true_cost_terms(sc, active, assignment, f, beta)


def repair_assignment(sc_new: Scenario, prev_assign: np.ndarray,
                      old_active: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """Repair a previous stable assignment onto a churned scenario — the ONE
    place the repair rules live, shared by ``rerun_incremental`` (warm path)
    and any cold re-solve that must be bit-comparable with it (the live
    loop's ``periodic-cold`` policy descends a fresh engine from exactly
    this repaired start, which is what makes the PR-4 warm/cold parity gate
    apply at every swap point).

    Rules: departures (active -> inactive) park at their nearest raw-reachable
    server (:func:`~repro.core.edge_association.parked_slots`); active
    devices whose previous server is no longer effectively reachable
    (arrivals holding a parked slot included, when that slot went out of
    reach) move to their nearest effectively-reachable server; everyone else
    keeps their slot. A displaced device with ZERO effectively-reachable
    servers raises :class:`~repro.core.edge_association.NoFeasibleServerError`
    — the old masked ``argmin`` silently parked it on server 0, poisoning
    server 0's group (and the warm/cold parity that hangs off it).

    Under ``sc_new.capacity``, keepers keep their slots (cap-feasible by
    induction: the previous stable point respected caps and the churn left
    them reachable) while displaced devices AND all arrivals are re-admitted
    greedily in device order via
    :func:`~repro.core.edge_association.greedy_admission` — an arrival's
    parked slot was never counted against a cap, so keeping it blindly
    could overflow the server. Admission failure raises the same error.

    Returns ``(assignment, departed, arrived, displaced)`` — the masks the
    caller needs for cache invalidation and trainer-state repair.
    """
    prev_assign = np.asarray(prev_assign)
    n = sc_new.n_devices
    dist = np.asarray(sc_new.dist)
    eff = np.asarray(sc_new.eff_avail)
    active = sc_new.active_mask
    old_active = np.asarray(old_active, dtype=bool)
    cap = sc_new.capacity
    departed = old_active & ~active
    arrived = active & ~old_active
    ok_now = eff[prev_assign, np.arange(n)]
    displaced = active & ~ok_now
    assign = prev_assign.copy()
    assign[departed] = parked_slots(sc_new)[departed]
    if cap is None:
        assign[displaced] = nearest_feasible(dist, eff,
                                             need=displaced)[displaced]
        return assign, departed, arrived, displaced
    readmit = displaced | arrived
    keep = active & ~readmit
    load = np.bincount(assign[keep], minlength=sc_new.n_servers)
    todo = np.flatnonzero(readmit)
    placed = greedy_admission(dist, eff, load, cap, todo)
    if (placed < 0).any():
        raise NoFeasibleServerError(todo[placed < 0], "no admitting server")
    assign[todo] = placed
    return assign, departed, arrived, displaced


class FastAssociationEngine:
    """Drop-in fast engine: same semantics as ``AssociationEngine.run_batched``
    (steepest permitted transfer per round, best sampled exchange when no
    transfer is permitted, identical permission rules and tolerances), with
    the whole loop resident on device.

    ``compact`` selects the sweep space — all of them run the SAME
    move-selection kernel, configured with different slot-index maps:
    ``False`` = dense (K, N) identity maps, ``True`` = flat compacted
    (K, R) reachable-slot space, ``"bucketed"`` = per-bucket (K_b, R_b)
    adaptive widths, and ``"auto"`` (default) picks flat compaction whenever
    availability is actually sparse (R < N). All spaces share move selection
    order, so they land on the same stable point.

    Differences from the reference: exchange candidates are drawn with the
    JAX PRNG instead of NumPy's (so exchange *sequences* differ run-to-run
    between engines), and all cost arithmetic is float32 on device rather
    than float64 on host. With ``exchange_samples=0`` the two engines are
    move-for-move identical on non-degenerate scenarios.

    ``shards=p`` runs the sweep shard_mapped over the first ``p`` jax
    devices (see "Sharded sweep" in the module docstring) — same move
    sequence, server-partitioned pricing; ``ra_backend="pallas"`` prices
    candidate groups through the fused golden-section kernel (``fast`` kind
    only). Both default off, leaving the classic bit-exact program.
    """

    def __init__(self, sc: Scenario, *, kind: str = "fast",
                 permission: str = "utilitarian", min_residual_group: int = 2,
                 seed: int = 0, rel_tol: float = 1e-5,
                 profile: str = "default", compact: bool | str = "auto",
                 shards: int | None = None, ra_backend: str = "xla"):
        assert permission in ("utilitarian", "pareto"), permission
        assert compact in (True, False, "auto", "bucketed"), compact
        if ra_backend not in ("xla", "pallas"):
            raise ValueError(f"ra_backend must be 'xla' or 'pallas', "
                             f"got {ra_backend!r}")
        if ra_backend == "pallas" and kind != "fast":
            raise ValueError(
                "ra_backend='pallas' fuses the golden-section fixed-point "
                "solver and therefore requires kind='fast'")
        self.ra_backend = ra_backend
        # ``shards=None`` is the classic single-device program (bit-exact
        # contract); ``shards=p`` runs the SAME impl shard_mapped over the
        # first p devices — p=1 exercises the sharded program on one device
        self.shards = None if shards is None else int(shards)
        if self.shards is None:
            self._mesh = None
        else:
            devs = jax.devices()
            if not 1 <= self.shards <= len(devs):
                raise ValueError(
                    f"shards={self.shards} but only {len(devs)} device(s) "
                    "visible (force more with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=<p> on CPU)")
            self._mesh = Mesh(np.array(devs[:self.shards]), (_SHARD_AXIS,))
        self.sc = sc
        self.kind = kind
        self.profile = profile
        self.permission = permission
        self.min_residual = min_residual_group
        self.rel_tol = rel_tol
        self.seed = seed
        self.solver = GroupSolver(sc, kind, seed=seed, profile=profile)
        # final reporting always happens at reference accuracy so costs are
        # comparable across screening profiles (the sweep may run coarser)
        self._eval_solver = self.solver.with_profile("default")
        self.rng = np.random.default_rng(seed)
        self._active = sc.active_mask
        self.avail = np.asarray(sc.eff_avail)
        # per-edge admission caps (None = the paper's uncapacitated model).
        # The kernel always takes a traced (K,) cap array; uncapped engines
        # pass N — never binding, since an inbound transfer needs a donor
        # group elsewhere — so toggling caps changes no jit signature and
        # the uncapped graph stays bit-identical to the historical one.
        self.cap = sc.capacity
        self._cap = jnp.asarray(
            np.full(sc.n_servers, sc.n_devices, np.int64)
            if self.cap is None else self.cap, jnp.int32)
        self.cloud_const = jnp.asarray(
            np.asarray(sc.lp.lambda_e * cloud_energy(sc.srv)
                       + sc.lp.lambda_t * cloud_delay(sc.srv),
                       dtype=np.float32))
        self.reach: ReachIndex | None = None
        self.reach_buckets: ReachBuckets | None = None
        try:
            self.reach = reach_index_map(np.asarray(sc.avail),
                                         active=self._active)
        except ValueError:
            if compact in (True, "bucketed"):
                raise
        if compact == "auto":
            if self.reach is None or self.reach.r_max >= sc.n_devices:
                compact = False
            else:
                # sparse reach -> compact; heavily padded flat maps (skewed
                # reach counts) -> the bucketed adaptive-width sweep
                compact = ("bucketed"
                           if (self.reach.padded_fraction
                               > BUCKETED_AUTO_THRESHOLD)
                           else True)
        self.compact = "bucketed" if compact == "bucketed" else bool(compact)
        if self.compact == "bucketed":
            self.reach_buckets = reach_index_map(
                np.asarray(sc.avail), bucketed=True, active=self._active)
        self._rebuild_space()
        self.last_state: dict | None = None   # debug: cur/toggle cache dump
        self.last_tier_moves: list[int] | None = None
        self.last_moves: int | None = None    # applied moves of the last sweep
        self._warm_cache: dict | None = None  # rerun_incremental state
        self.last_repaired_assignment: np.ndarray | None = None

    def _rebuild_space(self) -> None:
        """(Re)derive the sweep-space buffers — per-bucket index maps with
        pre-gathered constants plus the slot/bucket/row locators — from the
        current ``self.reach``/``self.reach_buckets``/``self.avail``. Cheap
        (pure gathers); the expensive state is the toggle cache, which
        :meth:`rerun_incremental` preserves across calls to this."""
        k, n = self.sc.n_servers, self.sc.n_devices
        servers = np.arange(k, dtype=np.int32)
        if self.compact == "bucketed":
            rbk = self.reach_buckets
            raw = [(b.servers, b.idx, b.valid, b.valid) for b in rbk.buckets]
            self._slot_of = jnp.asarray(rbk.slot)
            bucket_of, row_of = rbk.bucket_of, rbk.row_of
            # exchanges hit arbitrary server pairs, so they are priced in
            # one shared flat (K, R_max) space (same slot numbering as the
            # per-bucket maps) instead of once per width bucket
            self._ex_bucket = self._gather_bucket(
                servers, self.reach.idx, self.reach.valid, self.reach.valid)
        elif self.compact:
            r = self.reach
            raw = [(servers, r.idx, r.valid, r.valid)]
            self._slot_of = jnp.asarray(r.slot)
            bucket_of = np.zeros(k, np.int32)
            row_of = servers
            self._ex_bucket = None
        else:
            # dense sweep = identity index maps: every slot exists (so an
            # out-of-reach *current* member is still priced, like the host
            # reference engine), and availability only gates candidacy
            ident = np.broadcast_to(np.arange(n, dtype=np.int32), (k, n))
            raw = [(servers, ident, np.ones((k, n), bool), self.avail)]
            self._slot_of = jnp.asarray(np.ascontiguousarray(ident))
            bucket_of = np.zeros(k, np.int32)
            row_of = servers
            self._ex_bucket = None
        if self._mesh is None:
            self._buckets = tuple(self._gather_bucket(*r) for r in raw)
            self._bucket_of = jnp.asarray(bucket_of)
            self._row_of = jnp.asarray(row_of)
        else:
            self._buckets, self._bucket_of, self._row_of = \
                self._shard_space(raw, k)
        if self._ex_bucket is None:
            self._ex_bucket = (self._buckets[0] if self._mesh is None
                               else self._gather_bucket(*raw[0]))

    def _shard_space(self, raw: list, k: int):
        """Pad every bucket's row maps to a multiple of the mesh size for
        even partitioning along :data:`_SHARD_AXIS`, and build the per-shard
        (p, K) locator slices. Padded rows carry the sentinel server id K
        (their scatters drop, their gathers clamp, exists/ok stay False);
        a locator entry of ``len(raw)`` marks a server owned by another
        shard — the sweep's no-op switch branch."""
        p = self.shards
        nb = len(raw)
        bucket_of = np.full((p, k), nb, np.int32)
        row_of = np.zeros((p, k), np.int32)
        padded = []
        for b, (srvs, idx, exists, ok) in enumerate(raw):
            srvs = np.asarray(srvs, np.int32)
            kb = srvs.shape[0]
            rows_tot = -(-kb // p) * p
            extra = rows_tot - kb
            width = idx.shape[1]
            srvs_p = np.concatenate([srvs, np.full(extra, k, np.int32)])
            idx_p = np.concatenate(
                [idx, np.zeros((extra, width), idx.dtype)])
            exists_p = np.concatenate([exists, np.zeros((extra, width), bool)])
            ok_p = np.concatenate([ok, np.zeros((extra, width), bool)])
            padded.append(self._gather_bucket(srvs_p, idx_p, exists_p, ok_p))
            rows_per = rows_tot // p
            grow = np.arange(kb)
            bucket_of[grow // rows_per, srvs] = b
            row_of[grow // rows_per, srvs] = grow % rows_per
        return tuple(padded), jnp.asarray(bucket_of), jnp.asarray(row_of)

    def _gather_bucket(self, servers, idx, exists, ok) -> _Bucket:
        """Pre-gather every per-device RA quantity into this bucket's
        (K_b, R_b) slot space; per-server (1-D) leaves gather by server id."""
        srv = jnp.asarray(servers, jnp.int32)
        ridx = jnp.asarray(idx)
        rows = srv[:, None]
        consts = jax.tree.map(
            lambda x: x[rows, ridx] if x.ndim == 2 else x[srv],
            self.solver.consts)
        return _Bucket(servers=srv, idx=ridx,
                       exists=jnp.asarray(exists), ok=jnp.asarray(ok),
                       consts=consts,
                       random_f=self.solver.random_f[ridx],
                       inv_dist=self.solver.inv_dist[rows, ridx])

    def initial_assignment(self, init: str = "nearest") -> np.ndarray:
        return initial_assignment(self.sc, self.avail, self.rng, init)

    def evaluate_assignment(self, assignment: np.ndarray) -> float:
        """Reference-accuracy total system cost of an explicit assignment —
        the same evaluation ``_finalize`` applies to a run's stable point, so
        costs from different screening profiles (or no run at all) compare on
        one scale."""
        assignment = np.asarray(assignment)
        n, k = self.sc.n_devices, self.sc.n_servers
        member = self._member_of(assignment)
        sols = self._eval_solver.solve_batch(np.arange(k), member)
        return float(np.sum(np.asarray(sols.cost)
                            + np.where(member.any(axis=1),
                                       np.asarray(self.cloud_const), 0.0)))

    def run(self, init: str = "nearest", *, max_moves: int = 10_000,
            exchange_samples: int = DEFAULT_EXCHANGE_SAMPLES,
            assignment: np.ndarray | None = None, finalize: bool = True):
        """One adjustment-loop descent to the stable point.

        ``exchange_samples`` defaults to :data:`DEFAULT_EXCHANGE_SAMPLES`
        (= 64) — the one engine-wide default, shared with ``run_tiered``,
        ``rerun_incremental`` and the live loop — and works under
        ``shards=p`` too (the sampled-exchange pass is distributed with a
        bit-identical winner merge; see "Sharded sweep" in the module
        docstring). Pass 0 for a deterministic transfer-only sweep.

        ``finalize=False`` mirrors :meth:`rerun_incremental`'s fast path: it
        skips the reference-accuracy ``_finalize`` evaluation and returns
        just the (N,) stable assignment (read ``last_moves`` /
        ``stable_assignment`` for the rest) — so cold and warm re-solves can
        be timed symmetrically, with cost accounting on the caller's
        schedule.
        """
        assignment = (self.initial_assignment(init) if assignment is None
                      else np.asarray(assignment))
        assignment, member, moves, trace = self._sweep(
            assignment, self.profile, max_moves, exchange_samples,
            jax.random.PRNGKey(self.seed))
        if not finalize:
            return assignment.copy()
        return self._finalize(assignment, member, moves, trace)

    def run_tiered(self, init: str = "nearest", *,
                   tiers: str | tuple[str, ...] = "two_tier",
                   max_moves: int = 10_000,
                   exchange_samples: int = DEFAULT_EXCHANGE_SAMPLES,
                   tier_rel_tols: tuple[float, ...] | None = None,
                   assignment: np.ndarray | None = None) -> AssociationResult:
        """Two-tier (or n-tier) descent: drive each profile of ``tiers`` to
        its stable point, warm-starting from the previous tier's assignment.

        ``tiers`` is a :data:`repro.core.resource_allocation.TIER_PLANS` plan
        name or an explicit profile tuple; the engine's own ``profile`` is
        ignored by this driver. Coarse tiers apply the bulk of the moves at a
        fraction of default-accuracy sweep cost, and the final tier's polish
        recovers the reference-accuracy stable point. ``tier_rel_tols``
        optionally sets a per-tier stop tolerance (same length as the
        resolved plan): a looser leading tolerance stops the cheap tier at
        *near*-stability and leaves the long tail of sub-threshold moves to
        the tolerance the final tier declares stability at. The stop
        tolerance is a traced argument, so varying it never recompiles. The
        returned trace concatenates all tiers (each tier re-evaluates its
        warm start at its own accuracy, so seams may step, but every tier is
        monotone).
        """
        profiles = ra.resolve_tiers(tiers)
        rel_tols = (tuple(tier_rel_tols) if tier_rel_tols is not None
                    else (self.rel_tol,) * len(profiles))
        if len(rel_tols) != len(profiles):
            raise ValueError(
                f"tier_rel_tols has {len(rel_tols)} entries for "
                f"{len(profiles)} tiers")
        assignment = (self.initial_assignment(init) if assignment is None
                      else np.asarray(assignment))
        base_key = jax.random.PRNGKey(self.seed)
        total_moves = 0
        trace: list[float] = []
        tier_moves: list[int] = []
        member = None
        for i, (prof, tol) in enumerate(zip(profiles, rel_tols)):
            assignment, member, moves, tr = self._sweep(
                assignment, prof, max_moves, exchange_samples,
                jax.random.fold_in(base_key, i), rel_tol=tol)
            total_moves += moves
            tier_moves.append(moves)
            trace.extend(tr)
        self.last_tier_moves = tier_moves
        return self._finalize(assignment, member, total_moves, trace)

    def rerun_incremental(self, sc_new: Scenario, delta: ScenarioDelta, *,
                          max_moves: int = 10_000,
                          exchange_samples: int = DEFAULT_EXCHANGE_SAMPLES,
                          verify: bool = False, finalize: bool = True):
        """Re-converge after a :func:`repro.core.scenario.perturb_scenario`
        step WITHOUT rebuilding the expensive static state.

        The engine mutates itself onto ``sc_new``: the reach slot-index maps
        are patched in place (only overflowing buckets rebuild), the
        previous stable assignment is repaired on the host (departures
        leave their groups, arrivals and out-of-reach devices go to their
        nearest effectively-reachable server), and the adjustment loop
        restarts with the previous toggle-cost cache — only the rows of
        servers the delta or the repair touched are re-solved at init. From
        a near-stable warm start the descent needs a handful of moves where
        a cold start needs hundreds.

        The sweep runs at the profile that produced the cached rows (the
        last ``run``/``run_tiered`` tier), since cache entries from another
        profile would poison move selection. Chained deltas are supported:
        each call refreshes the cache for the next.

        ``verify=True`` is the hard parity gate: a cold engine is built on
        ``sc_new`` and descended from the same repaired assignment, and the
        two stable points must match bit-identically (raises otherwise).
        It re-pays the full rebuild, so it is for tests/benchmarks, not for
        the hot path. The parity holds with ``exchange_samples > 0`` (the
        :data:`DEFAULT_EXCHANGE_SAMPLES` default): both sides descend from
        the same repaired assignment, bitwise-equal caches and the same
        ``PRNGKey(seed)`` stream, so they draw and apply the same escape
        moves.

        ``finalize=False`` is the non-verifying fast path for per-round use
        (the live co-simulation's hot loop): it skips the reference-accuracy
        ``_finalize`` evaluation — which costs a full default-profile
        ``solve_batch`` — and returns just the (N,) stable assignment.
        The stable-point cache is refreshed either way, so the next
        ``rerun_incremental`` warm-starts identically, and the assignment
        stays readable afterwards via :attr:`stable_assignment`. System-cost
        accounting then happens separately (e.g. via
        :func:`assignment_true_cost`), on the caller's schedule rather than
        once per re-solve.
        """
        if self._warm_cache is None:
            raise RuntimeError(
                "rerun_incremental needs a prior run()/run_tiered() on this "
                "engine to warm-start from")
        cache = self._warm_cache
        profile = cache["profile"]
        prev_assign = np.asarray(cache["assignment"])
        old_active = self._active
        n, k = self.sc.n_devices, self.sc.n_servers
        if sc_new.n_devices != n or sc_new.n_servers != k:
            raise ValueError("rerun_incremental requires fixed (N, K); "
                             "churn uses the active mask, not resizing")
        new_cap = sc_new.capacity
        if ((self.cap is None) != (new_cap is None)
                or (self.cap is not None
                    and not np.array_equal(self.cap, new_cap))):
            # the traced cap array is engine state built at __init__; the
            # churn contract (diff_scenarios) keeps caps invariant anyway
            raise ValueError(
                "rerun_incremental requires churn-invariant max_devices; "
                "rebuild the engine to change capacities")

        # ---- swap the scenario and patch the static index maps ----
        self.sc = sc_new
        self._active = sc_new.active_mask.copy()
        self.avail = np.asarray(sc_new.eff_avail)
        if delta.moved.any():
            # distance-derived solver buffers (only the "proportional"
            # scheme reads them; RA constants are delta-invariant)
            inv = 1.0 / np.maximum(np.asarray(sc_new.dist), 1.0)
            self.solver.inv_dist = jnp.asarray(inv.astype(np.float32))
            self._eval_solver = self.solver.with_profile("default")
        raw = np.asarray(sc_new.avail)
        stale = np.asarray(delta.stale_servers, dtype=bool).copy()
        carry: list = [0] * len(self._buckets)
        if self.compact:
            # the flat map backs the flat sweep AND the bucketed mode's
            # shared exchange slot space; dense engines never read it after
            # __init__'s auto decision, so it is dropped rather than left
            # silently stale
            self.reach, flat_rebuilt = update_reach_index(
                self.reach, raw, active=self._active,
                changed_servers=delta.stale_servers)
        else:
            self.reach = None
        if self.compact == "bucketed":
            self.reach_buckets, carry = update_reach_buckets(
                self.reach_buckets, raw, active=self._active,
                changed_servers=delta.stale_servers)
        elif self.compact:
            carry = [None] if flat_rebuilt else [0]
        elif self.kind == "proportional" and delta.moved.any():
            # dense toggle rows span every device, so a moved device's
            # inv_dist change can touch any row's cached cost
            stale[:] = True
        self._rebuild_space()

        # ---- repair the previous stable assignment on the host ----
        assign, departed, arrived, displaced = repair_assignment(
            sc_new, prev_assign, old_active)
        # groups losing a member (departures + displaced previous members)
        stale[prev_assign[departed]] = True
        stale[prev_assign[displaced & old_active]] = True
        # groups gaining a member (every arrival joins *some* group)
        stale[assign[displaced]] = True
        stale[assign[arrived]] = True

        # ---- align cached toggle rows to the (possibly patched) layout ----
        toggles_warm = []
        for b, bd in enumerate(self._buckets):
            shape = tuple(bd.idx.shape)
            src = carry[b] if b < len(carry) else None
            if src is None or cache["toggles"][src].shape != shape:
                toggles_warm.append(jnp.zeros(shape, jnp.float32))
                srvs = np.asarray(bd.servers)
                stale[srvs[srvs < k]] = True   # skip sharded padding rows
            else:
                toggles_warm.append(jnp.asarray(cache["toggles"][src]))
        warm = (jnp.asarray(cache["cur"]), tuple(toggles_warm),
                jnp.asarray(stale))

        self.last_repaired_assignment = assign.copy()
        assignment, member, moves, trace = self._sweep(
            assign, profile, max_moves, exchange_samples,
            jax.random.PRNGKey(self.seed), warm=warm)
        if verify:
            cold = FastAssociationEngine(
                sc_new, kind=self.kind, permission=self.permission,
                min_residual_group=self.min_residual, seed=self.seed,
                rel_tol=self.rel_tol, profile=profile, compact=self.compact,
                shards=self.shards, ra_backend=self.ra_backend)
            ref = cold.run(assignment=self.last_repaired_assignment,
                           max_moves=max_moves,
                           exchange_samples=exchange_samples, finalize=False)
            if not np.array_equal(assignment, ref):
                raise AssertionError(
                    "incremental warm start diverged from the cold rebuild: "
                    f"{int((assignment != ref).sum())} "
                    "device placements differ")
        if not finalize:
            return assignment.copy()
        return self._finalize(assignment, member, moves, trace)

    @property
    def stable_assignment(self) -> np.ndarray | None:
        """The most recent stable-point assignment (parked slots included),
        readable after any ``run``/``run_tiered``/``rerun_incremental``
        without holding on to result objects — the handoff surface for
        external drivers polling the engine between re-solves. ``None``
        before the first run."""
        if self._warm_cache is None:
            return None
        return np.asarray(self._warm_cache["assignment"]).copy()

    def _member_of(self, assignment: np.ndarray) -> np.ndarray:
        return _dense_member(np.asarray(assignment), self._active,
                             self.sc.n_servers)

    def _sweep(self, assignment: np.ndarray, profile: str, max_moves: int,
               exchange_samples: int, key, rel_tol: float | None = None,
               warm=None):
        """One profile's adjustment loop; returns (assignment, dense member,
        n_moves, trace) and stashes the cache dump in ``last_state``."""
        rel_tol = self.rel_tol if rel_tol is None else rel_tol
        assignment = np.asarray(assignment)
        n, k = self.sc.n_devices, self.sc.n_servers
        member0 = self._member_of(assignment)
        if self.compact:
            # an out-of-reach assignment has no slot in compacted space: the
            # device would silently vanish from its group and the sweep's
            # slot_of gather would clamp to an unrelated device's toggle
            # cost, so reject it loudly (the dense path merely prices the
            # unreachable placement like the reference engine does)
            unreachable = self._active & ~self.avail[assignment, np.arange(n)]
            if unreachable.any():
                bad = np.flatnonzero(unreachable)[:8]
                raise ValueError(
                    "compact sweep requires every device assigned within "
                    f"reach; devices {bad.tolist()} are not (e.g. device "
                    f"{bad[0]} -> server {assignment[bad[0]]})")
        if self.cap is not None:
            # transfers are cap-gated and exchanges cap-neutral, so a sweep
            # preserves feasibility — but only if it STARTS feasible; an
            # over-cap explicit assignment would stay over-cap forever
            load = np.bincount(assignment[self._active], minlength=k)
            over = np.flatnonzero(load > self.cap)
            if over.size:
                raise ValueError(
                    f"assignment exceeds max_devices at server(s) "
                    f"{over.tolist()[:8]} (load "
                    f"{load[over].tolist()[:8]} > cap "
                    f"{self.cap[over].tolist()[:8]})")
        args = (jnp.asarray(member0), jnp.asarray(assignment, jnp.int32), key,
                self._buckets, self._ex_bucket, self._slot_of,
                self._bucket_of, self._row_of, self.cloud_const, self._cap,
                jnp.float32(rel_tol), warm)
        if self._mesh is None:
            member, assign, cur, toggles, moves, trace = _run_device(
                *args, kind=self.kind,
                profile=profile, permission=self.permission,
                min_residual=self.min_residual, max_moves=max_moves,
                exchange_samples=exchange_samples,
                ra_backend=self.ra_backend)
        else:
            runner = _sharded_runner(
                self._mesh, len(self._buckets), warm is not None,
                kind=self.kind, profile=profile, permission=self.permission,
                min_residual=self.min_residual, max_moves=max_moves,
                exchange_samples=exchange_samples,
                ra_backend=self.ra_backend)
            member, assign, cur, toggles, moves, trace = runner(*args)
        member_np = np.asarray(member)
        self.last_state = {"member": member_np,
                           "cur_cost": np.asarray(cur)}
        if self.compact == "bucketed":
            self.last_state.update(
                toggle_cost_buckets=[np.asarray(t) for t in toggles],
                reach_buckets=self.reach_buckets)
        elif self.compact:
            r = self.reach
            self.last_state.update(
                member_compact=(member_np[np.arange(k)[:, None], r.idx]
                                & r.valid),
                toggle_cost_compact=np.asarray(toggles[0]),
                reach=r)
        else:
            self.last_state.update(toggle_cost=np.asarray(toggles[0]))
        moves = int(moves)
        self.last_moves = moves
        trace = [float(x) for x in np.asarray(trace[:moves + 1], np.float64)]
        assign_np = np.asarray(assign, np.int64)
        # stable-point cache for rerun_incremental: everything a warm start
        # needs to skip the full toggle-cache init after a scenario delta
        self._warm_cache = {
            "assignment": assign_np.copy(),
            "cur": np.asarray(cur, np.float32),
            "toggles": [np.asarray(t) for t in toggles],
            "profile": profile,
        }
        return assign_np, member, moves, trace

    def _finalize(self, assignment, member, moves, trace) -> AssociationResult:
        k = self.sc.n_servers
        masks = np.asarray(member)
        sols = self._eval_solver.solve_batch(np.arange(k), masks)
        jmasks = jnp.asarray(masks)
        f = np.asarray(jnp.sum(jnp.where(jmasks, sols.f, 0.0), axis=0))
        beta = np.asarray(jnp.sum(jnp.where(jmasks, sols.beta, 0.0), axis=0))
        server_cost = np.asarray(sols.cost)
        total = float(np.sum(
            server_cost + np.where(masks.any(axis=1),
                                   np.asarray(self.cloud_const), 0.0)))
        # true (15)-(17) costs are over the active population only: inactive
        # devices hold no resources (f = beta = 0 in the masked sums above)
        # and must not enter the per-device energy/delay terms
        e, t, c = _true_cost_terms(self.sc, self._active, assignment, f, beta)
        return AssociationResult(
            assignment=assignment.copy(), f=f, beta=beta,
            server_cost=server_cost, total_cost=total,
            true_energy=float(e), true_delay=float(t), true_cost=float(c),
            n_adjustments=moves, n_rounds=moves, cost_trace=trace)
