"""Device-resident edge association — fused candidate sweeps with an
incremental toggle-cost delta cache, in dense or compacted slot space.

This is the performance engine behind Algorithm 3 / ``run_batched``: the whole
steepest-descent adjustment loop runs inside ONE jitted ``lax.while_loop``
with donated state buffers, so a full association run costs a single host
round-trip regardless of how many adjustments it applies. The reference
:class:`~repro.core.edge_association.AssociationEngine` instead drives every
round through Python loops, frozenset-keyed memo dicts, and one
``solve_batch`` host->device sync per candidate batch.

Dense design
------------
Association state is a ``(K, N)`` boolean membership mask on device. The key
data structure is the *toggle-cost cache*::

    toggle[k, n] = group cost of  member[k] XOR {n}
    cur[k]       = group cost of  member[k]

Because XOR adds ``n`` when it is absent and removes it when present,
``toggle`` simultaneously caches every "group k gains device n" candidate
(for non-members) and every "group k loses device n" candidate (for members)
— the two halves of any transfer. The delta of moving device ``n`` from its
server ``s = assign[n]`` to server ``k`` is then pure arithmetic::

    delta[k, n] = (toggle[s, n] - cur[s]) + (toggle[k, n] - cur[k])

so each steepest-descent round scans ALL N*K candidate transfers with zero
solver calls, picks the best permitted move via ``lax`` reductions, and only
then refreshes the cache. A move touches exactly two servers, so the refresh
is a fused vmapped solve of ``2*(N+1)`` groups (each touched server's current
mask plus its N single-device toggles). Group costs here always include the
server's cloud-aggregation constant when the group is non-empty, matching
``AssociationEngine.group_cost``.

Compacted reachable-set design (``compact=True``, auto-on for sparse reach)
---------------------------------------------------------------------------
The dense refresh prices ``2*(N+1)`` candidate groups of vector width N even
though a server can only ever gain devices it reaches. With the static
per-server index maps of :func:`repro.core.scenario.reach_index_map`
(``R`` = max reach count, padded), membership and toggle state live in
``(K, R)`` *compacted slot space*: RA constants, the fixed random-f draws and
inverse-distance rows are pre-gathered per server, so the per-move refresh
solves ``2*(R+1)`` groups of width R — an ``(N/R)^2``-ish cut that is what
makes full N=2000/K=50 convergence runs tractable (see
``benchmarks/assoc_scale.py`` for measured ratios). The candidate argmin runs
in the same compacted space with an explicit device-major tie-break key, so
move selection matches the dense engine order-for-order; the chosen move is
scattered back to the dense ``(K, N)`` mask kept alongside (two column
scatters per move) so finalization and debugging read ordinary dense state.
Padded slots carry garbage toggle costs by construction and are excluded from
every candidate mask; they never influence a move.

Sampled *exchanges* (Definition 5) ride the same fused sweep in both spaces:
when no transfer is permitted, a ``lax.cond`` branch draws candidate device
pairs with the on-device PRNG, evaluates both swapped groups for every pair
in one vmapped solve, and applies the best permitted swap followed by the
same two-row cache refresh. In compacted space the swapped masks are built by
XOR-ing one-hot slot encodings (an out-of-reach slot encodes as the all-zero
row, so unavailable swaps are naturally inert and additionally gated).

Two-tier descent (:meth:`FastAssociationEngine.run_tiered`)
-----------------------------------------------------------
Screening profiles trade solve accuracy for sweep speed but leave a ~1% cost
gap at the stable point. The tiered driver runs the adjustment loop once per
profile of a :data:`repro.core.resource_allocation.TIER_PLANS` plan (default
``"two_tier"`` = coarse then default), warm-starting each tier from the
previous tier's stable assignment. The coarse tier applies nearly all moves
cheaply; the default-accuracy polish then needs only a handful of moves to
recover the reference-accuracy stable point, at a fraction of a default-only
sweep's wall time. The concatenated ``cost_trace`` keeps each tier's
evaluation seam (tier boundaries re-evaluate the same assignment at the new
profile's accuracy, so the trace is monotone within tiers, not across them).

The per-group solver is :func:`repro.core.edge_association.solve_group`, so
every §V.A scheme kind works here; ``profile`` selects a
:data:`repro.core.resource_allocation.SCREEN_PROFILES` iteration preset
("default" reproduces the reference engine bit-for-bit on the solve level,
"screen"/"coarse" cut sweep cost ~2-4x for large-N scenarios).

Compilation: one XLA program per ``(N or R, K, max_moves, exchange_samples,
kind, profile, permission, min_residual)``. The jit cache is module-global,
so repeated engines on same-shaped scenarios reuse the compiled program.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import resource_allocation as ra
from repro.core.cost_model import cloud_delay, cloud_energy, global_cost
from repro.core.edge_association import (AssociationResult, GroupSolver,
                                         initial_assignment, solve_group)
from repro.core.scenario import ReachIndex, Scenario, reach_index_map

_INF = jnp.inf
_I32_BIG = np.iinfo(np.int32).max


def _group_cost_fn(kind, profile, consts, random_f, inv_dist, cloud_const):
    """(server_idx, mask) -> group cost incl. the non-empty cloud constant."""

    def cost(server_idx, mask):
        c = jax.tree.map(lambda x: x[server_idx], consts)
        sol = solve_group(kind, c, mask, random_f=random_f,
                          inv_dist_row=inv_dist[server_idx], profile=profile)
        return sol.cost + jnp.where(jnp.any(mask), cloud_const[server_idx], 0.0)

    return cost


def _compact_cost_fn(kind, profile, consts_c, random_f_c, inv_dist_c,
                     cloud_const):
    """Compacted-space twin of :func:`_group_cost_fn`: ``consts_c`` leaves,
    ``random_f_c`` and ``inv_dist_c`` are pre-gathered per server at its
    reachable-device indices, so masks are (R,) slot vectors."""

    def cost(server_idx, mask):
        c = jax.tree.map(lambda x: x[server_idx], consts_c)
        sol = solve_group(kind, c, mask, random_f=random_f_c[server_idx],
                          inv_dist_row=inv_dist_c[server_idx], profile=profile)
        return sol.cost + jnp.where(jnp.any(mask), cloud_const[server_idx], 0.0)

    return cost


@partial(jax.jit, donate_argnums=(0, 1),
         static_argnames=("kind", "profile", "permission", "min_residual",
                          "max_moves", "exchange_samples"))
def _run_device(member, assignment, key, consts, random_f, inv_dist, avail,
                cloud_const, rel_tol, *, kind, profile, permission,
                min_residual, max_moves, exchange_samples):
    """The whole adjustment loop as one device program (dense (K, N) space).

    Returns (member, assignment, cur, toggle, n_moves, trace); ``trace[i]``
    is the surrogate total after move i (trace[0] = initial total), padded
    with NaN past ``n_moves``.
    """
    k, n = member.shape
    cost = _group_cost_fn(kind, profile, consts, random_f, inv_dist,
                          cloud_const)
    cost_v = jax.vmap(cost)
    eye = jnp.eye(n, dtype=bool)
    idx_n = jnp.arange(n)
    i32 = jnp.int32

    def rows_costs(member, rows):
        """Solve each row's current group and all N single-device toggles."""
        base = member[rows]                                       # (R, n)
        masks = jnp.concatenate(
            [base[:, None, :], base[:, None, :] ^ eye[None]], axis=1)
        sids = jnp.repeat(rows, n + 1)
        return cost_v(sids, masks.reshape(-1, n)).reshape(rows.shape[0], n + 1)

    # ---- init: fill the full (K, N) toggle cache, one server at a time ----
    # (lax.map keeps peak memory at one server's (N+1, N) batch, which is
    # what allows N=2000-scale scenarios on a single host.)
    all_costs = lax.map(lambda s: rows_costs(member, s[None])[0],
                        jnp.arange(k, dtype=i32))                 # (k, n+1)
    cur0 = all_costs[:, 0]
    toggle0 = all_costs[:, 1:]

    trace0 = jnp.full(max_moves + 1, jnp.nan, cur0.dtype)
    trace0 = trace0.at[0].set(jnp.sum(cur0))

    def harmless(new, old):
        return new <= old + rel_tol * jnp.maximum(old, 1e-9)

    def refresh(member, rows, cur, toggle):
        costs = rows_costs(member, rows)                          # (2, n+1)
        return (cur.at[rows].set(costs[:, 0]),
                toggle.at[rows].set(costs[:, 1:]))

    def do_transfer(args, t_dev, t_src, t_dst):
        member, assign, key = args
        m2 = member.at[t_src, t_dev].set(False).at[t_dst, t_dev].set(True)
        a2 = assign.at[t_dev].set(t_dst)
        return (jnp.asarray(True), jnp.stack([t_src, t_dst]), m2, a2, key)

    def no_exchange(args):
        member, assign, key = args
        return (jnp.asarray(False), jnp.zeros(2, i32), member, assign, key)

    def do_exchange(args, cur):
        member, assign, key = args
        key, sub = jax.random.split(key)
        pairs = jax.random.randint(sub, (exchange_samples, 2), 0, n, dtype=i32)
        dn, dm = pairs[:, 0], pairs[:, 1]
        si, sj = assign[dn], assign[dm]
        okay = (dn != dm) & (si != sj) & avail[sj, dn] & avail[si, dm]
        both = eye[dn] | eye[dm]                                  # (E, n)
        gi = member[si] ^ both
        gj = member[sj] ^ both
        new_costs = cost_v(jnp.concatenate([si, sj]),
                           jnp.concatenate([gi, gj]))
        ci, cj = new_costs[:exchange_samples], new_costs[exchange_samples:]
        old = cur[si] + cur[sj]
        delta = ci + cj - old
        perm = okay & (delta < -rel_tol * jnp.maximum(old, 1e-9))
        if permission == "pareto":
            perm &= harmless(ci, cur[si]) & harmless(cj, cur[sj])
        masked = jnp.where(perm, delta, _INF)
        b = jnp.argmin(masked)
        applied = jnp.isfinite(masked[b])
        ri, rj = si[b], sj[b]
        m2 = member.at[ri].set(jnp.where(applied, gi[b], member[ri]))
        m2 = m2.at[rj].set(jnp.where(applied, gj[b], m2[rj]))
        a2 = assign.at[dn[b]].set(jnp.where(applied, sj[b], assign[dn[b]]))
        a2 = a2.at[dm[b]].set(jnp.where(applied, si[b], a2[dm[b]]))
        return (applied, jnp.stack([ri, rj]), m2, a2, key)

    def body(state):
        member, assign, cur, toggle, moves, key, trace, _ = state
        # -- scan all N*K transfer candidates from the cache (no solves) --
        cur_src = cur[assign]                                     # (n,)
        minus = toggle[assign, idx_n]                             # (n,)
        delta = (minus - cur_src)[None, :] + toggle - cur[:, None]
        scale = jnp.maximum(cur[:, None] + cur_src[None, :], 1e-9)
        gsize = jnp.sum(member, axis=1)
        valid = (avail & (jnp.arange(k, dtype=i32)[:, None] != assign[None, :])
                 & (gsize[assign] > min_residual)[None, :])
        permitted = valid & (delta < -rel_tol * scale)
        if permission == "pareto":
            permitted &= (harmless(toggle, cur[:, None])
                          & harmless(minus, cur_src)[None, :])
        # device-major flattening matches the reference engine's candidate
        # iteration order, so argmin tie-breaking is move-for-move identical
        flat = jnp.where(permitted, delta, _INF).T.reshape(-1)
        t_idx = jnp.argmin(flat)
        has_transfer = jnp.isfinite(flat[t_idx])
        t_dev = (t_idx // k).astype(i32)
        t_dst = (t_idx % k).astype(i32)
        t_src = assign[t_dev]

        args = (member, assign, key)
        if exchange_samples:
            applied, rows, member, assign, key = lax.cond(
                has_transfer,
                lambda a: do_transfer(a, t_dev, t_src, t_dst),
                lambda a: do_exchange(a, cur), args)
        else:
            applied, rows, member, assign, key = lax.cond(
                has_transfer,
                lambda a: do_transfer(a, t_dev, t_src, t_dst),
                no_exchange, args)
        cur, toggle = lax.cond(
            applied,
            lambda a: refresh(*a),
            lambda a: (a[2], a[3]), (member, rows, cur, toggle))
        moves = moves + applied.astype(i32)
        trace = trace.at[moves].set(
            jnp.where(applied, jnp.sum(cur), trace[moves]))
        return (member, assign, cur, toggle, moves, key, trace, ~applied)

    def cond(state):
        return (~state[-1]) & (state[4] < max_moves)

    state = (member, assignment, cur0, toggle0, jnp.asarray(0, i32), key,
             trace0, jnp.asarray(False))
    member, assignment, cur, toggle, moves, _, trace, _ = lax.while_loop(
        cond, body, state)
    return member, assignment, cur, toggle, moves, trace


@partial(jax.jit, donate_argnums=(0, 1, 2),
         static_argnames=("kind", "profile", "permission", "min_residual",
                          "max_moves", "exchange_samples"))
def _run_device_compact(member_c, member, assignment, key, consts_c,
                        random_f_c, inv_dist_c, reach_idx, slot_valid,
                        slot_of, cloud_const, rel_tol, *, kind, profile,
                        permission, min_residual, max_moves,
                        exchange_samples):
    """The adjustment loop in compacted (K, R) reachable-slot space.

    ``member_c[k, r]`` mirrors ``member[k, reach_idx[k, r]]`` for valid
    slots; the toggle cache, candidate argmin, and two-row refresh all run at
    width R, and each applied move is scattered back to the dense ``member``
    mask. Returns (member_c, member, assignment, cur, toggle_c, n_moves,
    trace) with the same trace convention as :func:`_run_device`.
    """
    k, r = member_c.shape
    n = member.shape[1]
    cost = _compact_cost_fn(kind, profile, consts_c, random_f_c, inv_dist_c,
                            cloud_const)
    cost_v = jax.vmap(cost)
    eye = jnp.eye(r, dtype=bool)
    idx_n = jnp.arange(n)
    idx_k = jnp.arange(k, dtype=jnp.int32)
    i32 = jnp.int32

    def rows_costs(member_c, rows):
        """Solve each row's current group and all R single-slot toggles."""
        base = member_c[rows]                                     # (B, r)
        masks = jnp.concatenate(
            [base[:, None, :], base[:, None, :] ^ eye[None]], axis=1)
        sids = jnp.repeat(rows, r + 1)
        return cost_v(sids, masks.reshape(-1, r)).reshape(rows.shape[0], r + 1)

    # ---- init: fill the (K, R) toggle cache, one server at a time ----
    all_costs = lax.map(lambda s: rows_costs(member_c, s[None])[0],
                        jnp.arange(k, dtype=i32))                 # (k, r+1)
    cur0 = all_costs[:, 0]
    toggle0 = all_costs[:, 1:]

    trace0 = jnp.full(max_moves + 1, jnp.nan, cur0.dtype)
    trace0 = trace0.at[0].set(jnp.sum(cur0))

    def harmless(new, old):
        return new <= old + rel_tol * jnp.maximum(old, 1e-9)

    def refresh(member_c, rows, cur, toggle):
        costs = rows_costs(member_c, rows)                        # (2, r+1)
        return (cur.at[rows].set(costs[:, 0]),
                toggle.at[rows].set(costs[:, 1:]))

    def onehot(slots):
        # slot == r (the out-of-reach sentinel) encodes as the all-zero row
        return jnp.arange(r)[None, :] == slots[:, None]

    def do_transfer(args, t_dev, t_src, t_dst):
        member_c, member, assign, key = args
        mc = member_c.at[t_src, slot_of[t_src, t_dev]].set(False)
        mc = mc.at[t_dst, slot_of[t_dst, t_dev]].set(True)
        m2 = member.at[t_src, t_dev].set(False).at[t_dst, t_dev].set(True)
        a2 = assign.at[t_dev].set(t_dst)
        return (jnp.asarray(True), jnp.stack([t_src, t_dst]), mc, m2, a2, key)

    def no_exchange(args):
        member_c, member, assign, key = args
        return (jnp.asarray(False), jnp.zeros(2, i32), member_c, member,
                assign, key)

    def do_exchange(args, cur):
        member_c, member, assign, key = args
        key, sub = jax.random.split(key)
        pairs = jax.random.randint(sub, (exchange_samples, 2), 0, n, dtype=i32)
        dn, dm = pairs[:, 0], pairs[:, 1]
        si, sj = assign[dn], assign[dm]
        sl_i_m = slot_of[si, dm]                       # dm's slot at si
        sl_j_n = slot_of[sj, dn]                       # dn's slot at sj
        okay = (dn != dm) & (si != sj) & (sl_j_n < r) & (sl_i_m < r)
        gi = member_c[si] ^ onehot(slot_of[si, dn]) ^ onehot(sl_i_m)
        gj = member_c[sj] ^ onehot(slot_of[sj, dm]) ^ onehot(sl_j_n)
        new_costs = cost_v(jnp.concatenate([si, sj]),
                           jnp.concatenate([gi, gj]))
        ci, cj = new_costs[:exchange_samples], new_costs[exchange_samples:]
        old = cur[si] + cur[sj]
        delta = ci + cj - old
        perm = okay & (delta < -rel_tol * jnp.maximum(old, 1e-9))
        if permission == "pareto":
            perm &= harmless(ci, cur[si]) & harmless(cj, cur[sj])
        masked = jnp.where(perm, delta, _INF)
        b = jnp.argmin(masked)
        applied = jnp.isfinite(masked[b])
        ri, rj = si[b], sj[b]
        dnb, dmb = dn[b], dm[b]
        mc = member_c.at[ri].set(jnp.where(applied, gi[b], member_c[ri]))
        mc = mc.at[rj].set(jnp.where(applied, gj[b], mc[rj]))
        m2 = member.at[ri, dnb].set(
            jnp.where(applied, False, member[ri, dnb]))
        m2 = m2.at[rj, dnb].set(jnp.where(applied, True, m2[rj, dnb]))
        m2 = m2.at[rj, dmb].set(jnp.where(applied, False, m2[rj, dmb]))
        m2 = m2.at[ri, dmb].set(jnp.where(applied, True, m2[ri, dmb]))
        a2 = assign.at[dnb].set(jnp.where(applied, rj, assign[dnb]))
        a2 = a2.at[dmb].set(jnp.where(applied, ri, a2[dmb]))
        return (applied, jnp.stack([ri, rj]), mc, m2, a2, key)

    def body(state):
        member_c, member, assign, cur, toggle, moves, key, trace, _ = state
        # -- scan all valid (server, slot) transfer candidates (no solves) --
        cur_src = cur[assign]                                     # (n,)
        minus = toggle[assign, slot_of[assign, idx_n]]            # (n,)
        minus_delta = minus - cur_src
        dev = reach_idx                                           # (k, r)
        src = assign[dev]                                         # (k, r)
        delta = minus_delta[dev] + toggle - cur[:, None]
        scale = jnp.maximum(cur[:, None] + cur_src[dev], 1e-9)
        gsize = jnp.sum(member_c, axis=1)
        valid = (slot_valid & (src != idx_k[:, None])
                 & (gsize[src] > min_residual))
        permitted = valid & (delta < -rel_tol * scale)
        if permission == "pareto":
            permitted &= (harmless(toggle, cur[:, None])
                          & harmless(minus, cur_src)[dev])
        masked = jnp.where(permitted, delta, _INF)
        best = jnp.min(masked)
        has_transfer = jnp.isfinite(best)
        # explicit device-major order key reproduces the dense engine's
        # argmin tie-breaking (smallest n*K + k among equal deltas)
        order = dev.astype(i32) * k + idx_k[:, None]
        tie = jnp.where(masked == best, order, _I32_BIG)
        p = jnp.argmin(tie)
        t_dev = dev.reshape(-1)[p]
        t_dst = (p // r).astype(i32)
        t_src = assign[t_dev]

        args = (member_c, member, assign, key)
        if exchange_samples:
            applied, rows, member_c, member, assign, key = lax.cond(
                has_transfer,
                lambda a: do_transfer(a, t_dev, t_src, t_dst),
                lambda a: do_exchange(a, cur), args)
        else:
            applied, rows, member_c, member, assign, key = lax.cond(
                has_transfer,
                lambda a: do_transfer(a, t_dev, t_src, t_dst),
                no_exchange, args)
        cur, toggle = lax.cond(
            applied,
            lambda a: refresh(*a),
            lambda a: (a[2], a[3]), (member_c, rows, cur, toggle))
        moves = moves + applied.astype(i32)
        trace = trace.at[moves].set(
            jnp.where(applied, jnp.sum(cur), trace[moves]))
        return (member_c, member, assign, cur, toggle, moves, key, trace,
                ~applied)

    def cond(state):
        return (~state[-1]) & (state[5] < max_moves)

    state = (member_c, member, assignment, cur0, toggle0,
             jnp.asarray(0, i32), key, trace0, jnp.asarray(False))
    (member_c, member, assignment, cur, toggle, moves, _, trace,
     _) = lax.while_loop(cond, body, state)
    return member_c, member, assignment, cur, toggle, moves, trace


class FastAssociationEngine:
    """Drop-in fast engine: same semantics as ``AssociationEngine.run_batched``
    (steepest permitted transfer per round, best sampled exchange when no
    transfer is permitted, identical permission rules and tolerances), with
    the whole loop resident on device.

    ``compact`` selects the sweep space: ``True`` runs in per-server
    compacted (K, R) reachable-slot space, ``False`` in dense (K, N) space,
    and ``"auto"`` (default) compacts whenever availability is actually
    sparse (R < N). Both spaces share move selection order, so they land on
    the same stable point.

    Differences from the reference: exchange candidates are drawn with the
    JAX PRNG instead of NumPy's (so exchange *sequences* differ run-to-run
    between engines), and all cost arithmetic is float32 on device rather
    than float64 on host. With ``exchange_samples=0`` the two engines are
    move-for-move identical on non-degenerate scenarios.
    """

    def __init__(self, sc: Scenario, *, kind: str = "fast",
                 permission: str = "utilitarian", min_residual_group: int = 2,
                 seed: int = 0, rel_tol: float = 1e-5,
                 profile: str = "default", compact: bool | str = "auto"):
        assert permission in ("utilitarian", "pareto"), permission
        assert compact in (True, False, "auto"), compact
        self.sc = sc
        self.kind = kind
        self.profile = profile
        self.permission = permission
        self.min_residual = min_residual_group
        self.rel_tol = rel_tol
        self.seed = seed
        self.solver = GroupSolver(sc, kind, seed=seed, profile=profile)
        # final reporting always happens at reference accuracy so costs are
        # comparable across screening profiles (the sweep may run coarser)
        self._eval_solver = self.solver.with_profile("default")
        self.rng = np.random.default_rng(seed)
        self.avail = np.asarray(sc.avail)
        self.cloud_const = jnp.asarray(
            np.asarray(sc.lp.lambda_e * cloud_energy(sc.srv)
                       + sc.lp.lambda_t * cloud_delay(sc.srv),
                       dtype=np.float32))
        self.reach: ReachIndex | None = None
        try:
            self.reach = reach_index_map(self.avail)
        except ValueError:
            if compact is True:
                raise
        if compact == "auto":
            compact = (self.reach is not None
                       and self.reach.r_max < sc.n_devices)
        self.compact = bool(compact)
        if self.compact:
            rows = jnp.arange(sc.n_servers)[:, None]
            ridx = jnp.asarray(self.reach.idx)
            # pre-gather every per-device quantity into (K, R) slot space;
            # scalar-per-server leaves (w, cloud consts) pass through
            self._consts_c = jax.tree.map(
                lambda x: x[rows, ridx] if x.ndim == 2 else x,
                self.solver.consts)
            self._random_f_c = self.solver.random_f[ridx]
            self._inv_dist_c = self.solver.inv_dist[rows, ridx]
            self._reach_idx = ridx
            self._slot_valid = jnp.asarray(self.reach.valid)
            self._slot_of = jnp.asarray(self.reach.slot)
        self.last_state: dict | None = None   # debug: cur/toggle cache dump
        self.last_tier_moves: list[int] | None = None

    def initial_assignment(self, init: str = "nearest") -> np.ndarray:
        return initial_assignment(self.sc, self.avail, self.rng, init)

    def evaluate_assignment(self, assignment: np.ndarray) -> float:
        """Reference-accuracy total system cost of an explicit assignment —
        the same evaluation ``_finalize`` applies to a run's stable point, so
        costs from different screening profiles (or no run at all) compare on
        one scale."""
        assignment = np.asarray(assignment)
        n, k = self.sc.n_devices, self.sc.n_servers
        member = np.zeros((k, n), dtype=bool)
        member[assignment, np.arange(n)] = True
        sols = self._eval_solver.solve_batch(np.arange(k), member)
        return float(np.sum(np.asarray(sols.cost)
                            + np.where(member.any(axis=1),
                                       np.asarray(self.cloud_const), 0.0)))

    def run(self, init: str = "nearest", *, max_moves: int = 10_000,
            exchange_samples: int = 64,
            assignment: np.ndarray | None = None) -> AssociationResult:
        assignment = (self.initial_assignment(init) if assignment is None
                      else np.asarray(assignment))
        assignment, member, moves, trace = self._sweep(
            assignment, self.profile, max_moves, exchange_samples,
            jax.random.PRNGKey(self.seed))
        return self._finalize(assignment, member, moves, trace)

    def run_tiered(self, init: str = "nearest", *,
                   tiers: str | tuple[str, ...] = "two_tier",
                   max_moves: int = 10_000, exchange_samples: int = 64,
                   tier_rel_tols: tuple[float, ...] | None = None,
                   assignment: np.ndarray | None = None) -> AssociationResult:
        """Two-tier (or n-tier) descent: drive each profile of ``tiers`` to
        its stable point, warm-starting from the previous tier's assignment.

        ``tiers`` is a :data:`repro.core.resource_allocation.TIER_PLANS` plan
        name or an explicit profile tuple; the engine's own ``profile`` is
        ignored by this driver. Coarse tiers apply the bulk of the moves at a
        fraction of default-accuracy sweep cost, and the final tier's polish
        recovers the reference-accuracy stable point. ``tier_rel_tols``
        optionally sets a per-tier stop tolerance (same length as the
        resolved plan): a looser leading tolerance stops the cheap tier at
        *near*-stability and leaves the long tail of sub-threshold moves to
        the tolerance the final tier declares stability at. The stop
        tolerance is a traced argument, so varying it never recompiles. The
        returned trace concatenates all tiers (each tier re-evaluates its
        warm start at its own accuracy, so seams may step, but every tier is
        monotone).
        """
        profiles = ra.resolve_tiers(tiers)
        rel_tols = (tuple(tier_rel_tols) if tier_rel_tols is not None
                    else (self.rel_tol,) * len(profiles))
        if len(rel_tols) != len(profiles):
            raise ValueError(
                f"tier_rel_tols has {len(rel_tols)} entries for "
                f"{len(profiles)} tiers")
        assignment = (self.initial_assignment(init) if assignment is None
                      else np.asarray(assignment))
        base_key = jax.random.PRNGKey(self.seed)
        total_moves = 0
        trace: list[float] = []
        tier_moves: list[int] = []
        member = None
        for i, (prof, tol) in enumerate(zip(profiles, rel_tols)):
            assignment, member, moves, tr = self._sweep(
                assignment, prof, max_moves, exchange_samples,
                jax.random.fold_in(base_key, i), rel_tol=tol)
            total_moves += moves
            tier_moves.append(moves)
            trace.extend(tr)
        self.last_tier_moves = tier_moves
        return self._finalize(assignment, member, total_moves, trace)

    def _sweep(self, assignment: np.ndarray, profile: str, max_moves: int,
               exchange_samples: int, key, rel_tol: float | None = None):
        """One profile's adjustment loop; returns (assignment, dense member,
        n_moves, trace) and stashes the cache dump in ``last_state``."""
        rel_tol = self.rel_tol if rel_tol is None else rel_tol
        assignment = np.asarray(assignment)
        n, k = self.sc.n_devices, self.sc.n_servers
        member0 = np.zeros((k, n), dtype=bool)
        member0[assignment, np.arange(n)] = True
        if self.compact:
            # an out-of-reach assignment has no slot in compacted space: the
            # device would silently vanish from its group and the sweep's
            # slot_of gather would clamp to an unrelated device's toggle
            # cost, so reject it loudly (the dense path merely prices the
            # unreachable placement like the reference engine does)
            unreachable = ~self.avail[assignment, np.arange(n)]
            if unreachable.any():
                bad = np.flatnonzero(unreachable)[:8]
                raise ValueError(
                    "compact sweep requires every device assigned within "
                    f"reach; devices {bad.tolist()} are not (e.g. device "
                    f"{bad[0]} -> server {assignment[bad[0]]})")
            member_c0 = ((assignment[self.reach.idx]
                          == np.arange(k)[:, None]) & self.reach.valid)
            member_c, member, assign, cur, toggle, moves, trace = \
                _run_device_compact(
                    jnp.asarray(member_c0), jnp.asarray(member0),
                    jnp.asarray(assignment, jnp.int32), key,
                    self._consts_c, self._random_f_c, self._inv_dist_c,
                    self._reach_idx, self._slot_valid, self._slot_of,
                    self.cloud_const, jnp.float32(rel_tol),
                    kind=self.kind, profile=profile,
                    permission=self.permission,
                    min_residual=self.min_residual, max_moves=max_moves,
                    exchange_samples=exchange_samples)
            self.last_state = {"member": np.asarray(member),
                               "member_compact": np.asarray(member_c),
                               "cur_cost": np.asarray(cur),
                               "toggle_cost_compact": np.asarray(toggle),
                               "reach": self.reach}
        else:
            member, assign, cur, toggle, moves, trace = _run_device(
                jnp.asarray(member0), jnp.asarray(assignment, jnp.int32),
                key, self.solver.consts, self.solver.random_f,
                self.solver.inv_dist, jnp.asarray(self.avail),
                self.cloud_const, jnp.float32(rel_tol), kind=self.kind,
                profile=profile, permission=self.permission,
                min_residual=self.min_residual, max_moves=max_moves,
                exchange_samples=exchange_samples)
            self.last_state = {"member": np.asarray(member),
                               "cur_cost": np.asarray(cur),
                               "toggle_cost": np.asarray(toggle)}
        moves = int(moves)
        trace = [float(x) for x in np.asarray(trace[:moves + 1], np.float64)]
        return np.asarray(assign, np.int64), member, moves, trace

    def _finalize(self, assignment, member, moves, trace) -> AssociationResult:
        k = self.sc.n_servers
        masks = np.asarray(member)
        sols = self._eval_solver.solve_batch(np.arange(k), masks)
        jmasks = jnp.asarray(masks)
        f = np.asarray(jnp.sum(jnp.where(jmasks, sols.f, 0.0), axis=0))
        beta = np.asarray(jnp.sum(jnp.where(jmasks, sols.beta, 0.0), axis=0))
        server_cost = np.asarray(sols.cost)
        total = float(np.sum(
            server_cost + np.where(masks.any(axis=1),
                                   np.asarray(self.cloud_const), 0.0)))
        e, t, c = global_cost(self.sc.dev, self.sc.srv,
                              jnp.asarray(assignment), jnp.asarray(f),
                              jnp.asarray(np.maximum(beta, 1e-9)), self.sc.lp)
        return AssociationResult(
            assignment=assignment.copy(), f=f, beta=beta,
            server_cost=server_cost, total_cost=total,
            true_energy=float(e), true_delay=float(t), true_cost=float(c),
            n_adjustments=moves, n_rounds=moves, cost_trace=trace)
