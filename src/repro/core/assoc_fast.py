"""Device-resident edge association — fused candidate sweep with an
incremental toggle-cost delta cache.

This is the performance engine behind Algorithm 3 / ``run_batched``: the whole
steepest-descent adjustment loop runs inside ONE jitted ``lax.while_loop``
with donated state buffers, so a full association run costs a single host
round-trip regardless of how many adjustments it applies. The reference
:class:`~repro.core.edge_association.AssociationEngine` instead drives every
round through Python loops, frozenset-keyed memo dicts, and one
``solve_batch`` host->device sync per candidate batch.

Design
------
Association state is a ``(K, N)`` boolean membership mask on device. The key
data structure is the *toggle-cost cache*::

    toggle[k, n] = group cost of  member[k] XOR {n}
    cur[k]       = group cost of  member[k]

Because XOR adds ``n`` when it is absent and removes it when present,
``toggle`` simultaneously caches every "group k gains device n" candidate
(for non-members) and every "group k loses device n" candidate (for members)
— the two halves of any transfer. The delta of moving device ``n`` from its
server ``s = assign[n]`` to server ``k`` is then pure arithmetic::

    delta[k, n] = (toggle[s, n] - cur[s]) + (toggle[k, n] - cur[k])

so each steepest-descent round scans ALL N*K candidate transfers with zero
solver calls, picks the best permitted move via ``lax`` reductions, and only
then refreshes the cache. A move touches exactly two servers, so the refresh
is a fused vmapped solve of ``2*(N+1)`` groups (each touched server's current
mask plus its N single-device toggles) — O(K-free) fresh solves per move
instead of the O(4*N*K) candidate pairs the naive sweep pays. Group costs
here always include the server's cloud-aggregation constant when the group is
non-empty, matching ``AssociationEngine.group_cost``.

Sampled *exchanges* (Definition 5) ride the same fused sweep: when no
transfer is permitted, a ``lax.cond`` branch draws candidate device pairs
with the on-device PRNG, evaluates both swapped groups for every pair in one
vmapped solve, and applies the best permitted swap followed by the same
two-row cache refresh.

The per-group solver is :func:`repro.core.edge_association.solve_group`, so
every §V.A scheme kind works here; ``profile`` selects a
:data:`repro.core.resource_allocation.SCREEN_PROFILES` iteration preset
("default" reproduces the reference engine bit-for-bit on the solve level,
"screen"/"coarse" cut sweep cost ~2-4x for large-N scenarios).

Compilation: one XLA program per ``(N, K, max_moves, exchange_samples, kind,
profile, permission, min_residual)`` — not one per power-of-two batch bucket.
The jit cache is module-global, so repeated engines on same-shaped scenarios
reuse the compiled program.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import resource_allocation as ra
from repro.core.cost_model import cloud_delay, cloud_energy, global_cost
from repro.core.edge_association import (AssociationResult, GroupSolver,
                                         initial_assignment, solve_group)
from repro.core.scenario import Scenario

_INF = jnp.inf


def _group_cost_fn(kind, profile, consts, random_f, inv_dist, cloud_const):
    """(server_idx, mask) -> group cost incl. the non-empty cloud constant."""

    def cost(server_idx, mask):
        c = jax.tree.map(lambda x: x[server_idx], consts)
        sol = solve_group(kind, c, mask, random_f=random_f,
                          inv_dist_row=inv_dist[server_idx], profile=profile)
        return sol.cost + jnp.where(jnp.any(mask), cloud_const[server_idx], 0.0)

    return cost


@partial(jax.jit, donate_argnums=(0, 1),
         static_argnames=("kind", "profile", "permission", "min_residual",
                          "max_moves", "exchange_samples"))
def _run_device(member, assignment, key, consts, random_f, inv_dist, avail,
                cloud_const, rel_tol, *, kind, profile, permission,
                min_residual, max_moves, exchange_samples):
    """The whole adjustment loop as one device program.

    Returns (member, assignment, cur, toggle, n_moves, trace); ``trace[i]``
    is the surrogate total after move i (trace[0] = initial total), padded
    with NaN past ``n_moves``.
    """
    k, n = member.shape
    cost = _group_cost_fn(kind, profile, consts, random_f, inv_dist,
                          cloud_const)
    cost_v = jax.vmap(cost)
    eye = jnp.eye(n, dtype=bool)
    idx_n = jnp.arange(n)
    i32 = jnp.int32

    def rows_costs(member, rows):
        """Solve each row's current group and all N single-device toggles."""
        base = member[rows]                                       # (R, n)
        masks = jnp.concatenate(
            [base[:, None, :], base[:, None, :] ^ eye[None]], axis=1)
        sids = jnp.repeat(rows, n + 1)
        return cost_v(sids, masks.reshape(-1, n)).reshape(rows.shape[0], n + 1)

    # ---- init: fill the full (K, N) toggle cache, one server at a time ----
    # (lax.map keeps peak memory at one server's (N+1, N) batch, which is
    # what allows N=2000-scale scenarios on a single host.)
    all_costs = lax.map(lambda s: rows_costs(member, s[None])[0],
                        jnp.arange(k, dtype=i32))                 # (k, n+1)
    cur0 = all_costs[:, 0]
    toggle0 = all_costs[:, 1:]

    trace0 = jnp.full(max_moves + 1, jnp.nan, cur0.dtype)
    trace0 = trace0.at[0].set(jnp.sum(cur0))

    def harmless(new, old):
        return new <= old + rel_tol * jnp.maximum(old, 1e-9)

    def refresh(member, rows, cur, toggle):
        costs = rows_costs(member, rows)                          # (2, n+1)
        return (cur.at[rows].set(costs[:, 0]),
                toggle.at[rows].set(costs[:, 1:]))

    def do_transfer(args, t_dev, t_src, t_dst):
        member, assign, key = args
        m2 = member.at[t_src, t_dev].set(False).at[t_dst, t_dev].set(True)
        a2 = assign.at[t_dev].set(t_dst)
        return (jnp.asarray(True), jnp.stack([t_src, t_dst]), m2, a2, key)

    def no_exchange(args):
        member, assign, key = args
        return (jnp.asarray(False), jnp.zeros(2, i32), member, assign, key)

    def do_exchange(args, cur):
        member, assign, key = args
        key, sub = jax.random.split(key)
        pairs = jax.random.randint(sub, (exchange_samples, 2), 0, n, dtype=i32)
        dn, dm = pairs[:, 0], pairs[:, 1]
        si, sj = assign[dn], assign[dm]
        okay = (dn != dm) & (si != sj) & avail[sj, dn] & avail[si, dm]
        both = eye[dn] | eye[dm]                                  # (E, n)
        gi = member[si] ^ both
        gj = member[sj] ^ both
        new_costs = cost_v(jnp.concatenate([si, sj]),
                           jnp.concatenate([gi, gj]))
        ci, cj = new_costs[:exchange_samples], new_costs[exchange_samples:]
        old = cur[si] + cur[sj]
        delta = ci + cj - old
        perm = okay & (delta < -rel_tol * jnp.maximum(old, 1e-9))
        if permission == "pareto":
            perm &= harmless(ci, cur[si]) & harmless(cj, cur[sj])
        masked = jnp.where(perm, delta, _INF)
        b = jnp.argmin(masked)
        applied = jnp.isfinite(masked[b])
        ri, rj = si[b], sj[b]
        m2 = member.at[ri].set(jnp.where(applied, gi[b], member[ri]))
        m2 = m2.at[rj].set(jnp.where(applied, gj[b], m2[rj]))
        a2 = assign.at[dn[b]].set(jnp.where(applied, sj[b], assign[dn[b]]))
        a2 = a2.at[dm[b]].set(jnp.where(applied, si[b], a2[dm[b]]))
        return (applied, jnp.stack([ri, rj]), m2, a2, key)

    def body(state):
        member, assign, cur, toggle, moves, key, trace, _ = state
        # -- scan all N*K transfer candidates from the cache (no solves) --
        cur_src = cur[assign]                                     # (n,)
        minus = toggle[assign, idx_n]                             # (n,)
        delta = (minus - cur_src)[None, :] + toggle - cur[:, None]
        scale = jnp.maximum(cur[:, None] + cur_src[None, :], 1e-9)
        gsize = jnp.sum(member, axis=1)
        valid = (avail & (jnp.arange(k, dtype=i32)[:, None] != assign[None, :])
                 & (gsize[assign] > min_residual)[None, :])
        permitted = valid & (delta < -rel_tol * scale)
        if permission == "pareto":
            permitted &= (harmless(toggle, cur[:, None])
                          & harmless(minus, cur_src)[None, :])
        # device-major flattening matches the reference engine's candidate
        # iteration order, so argmin tie-breaking is move-for-move identical
        flat = jnp.where(permitted, delta, _INF).T.reshape(-1)
        t_idx = jnp.argmin(flat)
        has_transfer = jnp.isfinite(flat[t_idx])
        t_dev = (t_idx // k).astype(i32)
        t_dst = (t_idx % k).astype(i32)
        t_src = assign[t_dev]

        args = (member, assign, key)
        if exchange_samples:
            applied, rows, member, assign, key = lax.cond(
                has_transfer,
                lambda a: do_transfer(a, t_dev, t_src, t_dst),
                lambda a: do_exchange(a, cur), args)
        else:
            applied, rows, member, assign, key = lax.cond(
                has_transfer,
                lambda a: do_transfer(a, t_dev, t_src, t_dst),
                no_exchange, args)
        cur, toggle = lax.cond(
            applied,
            lambda a: refresh(*a),
            lambda a: (a[2], a[3]), (member, rows, cur, toggle))
        moves = moves + applied.astype(i32)
        trace = trace.at[moves].set(
            jnp.where(applied, jnp.sum(cur), trace[moves]))
        return (member, assign, cur, toggle, moves, key, trace, ~applied)

    def cond(state):
        return (~state[-1]) & (state[4] < max_moves)

    state = (member, assignment, cur0, toggle0, jnp.asarray(0, i32), key,
             trace0, jnp.asarray(False))
    member, assignment, cur, toggle, moves, _, trace, _ = lax.while_loop(
        cond, body, state)
    return member, assignment, cur, toggle, moves, trace


class FastAssociationEngine:
    """Drop-in fast engine: same semantics as ``AssociationEngine.run_batched``
    (steepest permitted transfer per round, best sampled exchange when no
    transfer is permitted, identical permission rules and tolerances), with
    the whole loop resident on device.

    Differences from the reference: exchange candidates are drawn with the
    JAX PRNG instead of NumPy's (so exchange *sequences* differ run-to-run
    between engines), and all cost arithmetic is float32 on device rather
    than float64 on host. With ``exchange_samples=0`` the two engines are
    move-for-move identical on non-degenerate scenarios.
    """

    def __init__(self, sc: Scenario, *, kind: str = "fast",
                 permission: str = "utilitarian", min_residual_group: int = 2,
                 seed: int = 0, rel_tol: float = 1e-5,
                 profile: str = "default"):
        assert permission in ("utilitarian", "pareto"), permission
        self.sc = sc
        self.kind = kind
        self.profile = profile
        self.permission = permission
        self.min_residual = min_residual_group
        self.rel_tol = rel_tol
        self.seed = seed
        self.solver = GroupSolver(sc, kind, seed=seed, profile=profile)
        # final reporting always happens at reference accuracy so costs are
        # comparable across screening profiles (the sweep may run coarser)
        self._eval_solver = self.solver.with_profile("default")
        self.rng = np.random.default_rng(seed)
        self.avail = np.asarray(sc.avail)
        self.cloud_const = jnp.asarray(
            np.asarray(sc.lp.lambda_e * cloud_energy(sc.srv)
                       + sc.lp.lambda_t * cloud_delay(sc.srv),
                       dtype=np.float32))
        self.last_state: dict | None = None   # debug: cur/toggle cache dump

    def initial_assignment(self, init: str = "nearest") -> np.ndarray:
        return initial_assignment(self.sc, self.avail, self.rng, init)

    def run(self, init: str = "nearest", *, max_moves: int = 10_000,
            exchange_samples: int = 64,
            assignment: np.ndarray | None = None) -> AssociationResult:
        assignment = (self.initial_assignment(init) if assignment is None
                      else np.asarray(assignment))
        n, k = self.sc.n_devices, self.sc.n_servers
        member0 = np.zeros((k, n), dtype=bool)
        member0[assignment, np.arange(n)] = True
        member, assign, cur, toggle, moves, trace = _run_device(
            jnp.asarray(member0), jnp.asarray(assignment, jnp.int32),
            jax.random.PRNGKey(self.seed), self.solver.consts,
            self.solver.random_f, self.solver.inv_dist,
            jnp.asarray(self.avail), self.cloud_const,
            jnp.float32(self.rel_tol), kind=self.kind, profile=self.profile,
            permission=self.permission, min_residual=self.min_residual,
            max_moves=max_moves, exchange_samples=exchange_samples)
        moves = int(moves)
        self.last_state = {"member": np.asarray(member),
                           "cur_cost": np.asarray(cur),
                           "toggle_cost": np.asarray(toggle)}
        trace = [float(x) for x in np.asarray(trace[:moves + 1], np.float64)]
        return self._finalize(np.asarray(assign, np.int64), member,
                              moves, trace)

    def _finalize(self, assignment, member, moves, trace) -> AssociationResult:
        k = self.sc.n_servers
        masks = np.asarray(member)
        sols = self._eval_solver.solve_batch(np.arange(k), masks)
        jmasks = jnp.asarray(masks)
        f = np.asarray(jnp.sum(jnp.where(jmasks, sols.f, 0.0), axis=0))
        beta = np.asarray(jnp.sum(jnp.where(jmasks, sols.beta, 0.0), axis=0))
        server_cost = np.asarray(sols.cost)
        total = float(np.sum(
            server_cost + np.where(masks.any(axis=1),
                                   np.asarray(self.cloud_const), 0.0)))
        e, t, c = global_cost(self.sc.dev, self.sc.srv,
                              jnp.asarray(assignment), jnp.asarray(f),
                              jnp.asarray(np.maximum(beta, 1e-9)), self.sc.lp)
        return AssociationResult(
            assignment=assignment.copy(), f=f, beta=beta,
            server_cost=server_cost, total_cost=total,
            true_energy=float(e), true_delay=float(t), true_cost=float(c),
            n_adjustments=moves, n_rounds=moves, cost_trace=trace)
