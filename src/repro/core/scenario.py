"""Random HFEL scenario generation following the paper's Table II.

Devices and edge servers are dropped uniformly in a 500m x 500m area; the
channel gain follows the standard cellular path-loss model
``PL(dB) = 128.1 + 37.6 log10(d_km)`` (the paper cites [17] for the channel
set-up). Table II values:

  Edge bandwidth             10 MHz
  Device transmit power      200 mW
  Device CPU frequency       [1, 10] GHz
  Processing density         [30, 100] cycle/bit
  Background noise           1e-8 W
  Device training size       [5, 10] MB
  Updated model size         25000 nats
  Capacitance coefficient    2e-28
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro.core.cost_model import DeviceParams, LearningParams, ServerParams


@dataclass(frozen=True)
class ReachIndex:
    """Static per-server compaction maps of a (K, N) availability matrix.

    ``idx[k, r]`` is the device index occupying reachable slot ``r`` of
    server ``k`` (devices in ascending order, padded with 0 past the server's
    reach count); ``valid[k, r]`` marks real slots; ``slot[k, n]`` inverts the
    map (slot of device ``n`` at server ``k``, or ``r_max`` when ``n`` is out
    of reach — a deliberate out-of-range sentinel so one-hot encodings of an
    invalid slot are all-zero). ``r_max`` is the widest reach count, i.e. the
    compacted buffer width shared by all servers.
    """

    idx: np.ndarray        # (K, R) int32
    valid: np.ndarray      # (K, R) bool
    slot: np.ndarray       # (K, N) int32, r_max == "unreachable"
    r_max: int

    @property
    def density(self) -> float:
        return float(self.valid.mean())

    @property
    def padded_fraction(self) -> float:
        """Fraction of compacted slots that are padding (wasted sweep work)."""
        return 1.0 - self.density


@dataclass(frozen=True)
class ReachBucket:
    """One width-bucket of :class:`ReachBuckets`: the servers whose reach
    count shares a binary magnitude, compacted at that bucket's own width."""

    servers: np.ndarray    # (K_b,) int32 global server ids
    idx: np.ndarray        # (K_b, R_b) int32 device per slot (0-padded)
    valid: np.ndarray      # (K_b, R_b) bool — real slots
    width: int             # R_b = widest reach count in this bucket
    key: int = -1          # binary magnitude ceil(log2(count)) of its servers


@dataclass(frozen=True)
class ReachBuckets:
    """Adaptive-width compaction maps: servers grouped into binary buckets by
    reach count (same power-of-two scheme as ``GroupSolver.solve_batch``'s
    chunking), each bucket compacted to its own slot width R_b instead of
    every server padding to the global max R. ``slot``/``bucket_of``/
    ``row_of`` locate any (server, device) pair: device ``n`` lives at slot
    ``slot[k, n]`` of row ``row_of[k]`` in bucket ``bucket_of[k]`` (slot
    ``r_max`` is the shared out-of-reach sentinel — it is >= every bucket
    width, so per-bucket ``slot < R_b`` tests reject it)."""

    buckets: tuple[ReachBucket, ...]
    bucket_of: np.ndarray  # (K,) int32
    row_of: np.ndarray     # (K,) int32 — row within the owning bucket
    slot: np.ndarray       # (K, N) int32, r_max == "unreachable"
    r_max: int

    @property
    def padded_fraction(self) -> float:
        total = sum(b.idx.size for b in self.buckets)
        real = sum(int(b.valid.sum()) for b in self.buckets)
        return 1.0 - real / max(total, 1)


def _fill_reach_row(reach: np.ndarray, idx_row: np.ndarray,
                    valid_row: np.ndarray, slot_row: np.ndarray,
                    sentinel: int) -> None:
    """Write ONE server's compacted row in place — the ONE place slot
    numbering / padding semantics live, shared by the from-scratch builder
    and both incremental patchers: ascending device ids in the leading
    slots (0-padded past the reach count), matching validity flags, and the
    inverse slot map with ``sentinel`` marking out-of-reach devices."""
    idx_row[:] = 0
    valid_row[:] = False
    idx_row[:reach.size] = reach
    valid_row[:reach.size] = True
    slot_row[:] = sentinel
    slot_row[reach] = np.arange(reach.size, dtype=np.int32)


def reach_index_map(avail: np.ndarray, *, bucketed: bool = False,
                    active: np.ndarray | None = None):
    """Compute the compacted reachable-set index maps of ``avail`` (K, N).

    The fused candidate sweeps in :mod:`repro.core.assoc_fast` run in this
    compacted (K, R) slot space: with sparse availability R << N, so both the
    number of candidate groups per refresh and the vector width of every
    group solve shrink by the reach density. Every server must reach at least
    one device only if it is ever used; zero-reach *devices* are rejected
    because they cannot be associated anywhere (constraint 17e).

    ``bucketed=True`` returns :class:`ReachBuckets` instead: servers are
    grouped by ``ceil(log2(reach_count))`` and each bucket is compacted at
    its own width, so one dense-reach server no longer pads every other
    server's row to the global max (see ``padded_fraction``).

    ``active`` (N,) bool restricts the maps to the active device population
    of a churn scenario: inactive devices occupy no slot anywhere (they can
    never be candidates) and are exempt from the must-reach-one check.
    """
    avail = np.asarray(avail, dtype=bool)
    if active is not None:
        avail = avail & np.asarray(active, dtype=bool)[None, :]
    need_reach = (np.ones(avail.shape[1], bool) if active is None
                  else np.asarray(active, dtype=bool))
    if not avail.any(axis=0)[need_reach].all():
        raise ValueError("every device must reach at least one server")
    k, n = avail.shape
    counts = avail.sum(axis=1)
    r_max = int(counts.max()) if k else 0

    def fill(servers, width, slot):
        """Fill one group's (idx, valid) rows and its servers' slot-map
        rows via :func:`_fill_reach_row`."""
        idx = np.zeros((len(servers), width), dtype=np.int32)
        valid = np.zeros((len(servers), width), dtype=bool)
        for row, srv in enumerate(servers):
            _fill_reach_row(np.flatnonzero(avail[srv]), idx[row],
                            valid[row], slot[srv], r_max)
        return idx, valid

    slot = np.full((k, n), r_max, dtype=np.int32)
    if not bucketed:
        idx, valid = fill(range(k), r_max, slot)
        return ReachIndex(idx=idx, valid=valid, slot=slot, r_max=r_max)

    # binary bucketing: key = ceil(log2(count)); a zero-reach server (legal
    # when it is simply never used) joins the narrowest bucket
    keys = np.array([max(int(c) - 1, 0).bit_length() for c in counts])
    buckets = []
    bucket_of = np.zeros(k, dtype=np.int32)
    row_of = np.zeros(k, dtype=np.int32)
    for b, key in enumerate(sorted(set(keys.tolist()))):
        servers = np.flatnonzero(keys == key).astype(np.int32)
        width = max(int(counts[servers].max()), 1)
        idx, valid = fill(servers, width, slot)
        bucket_of[servers] = b
        row_of[servers] = np.arange(servers.size, dtype=np.int32)
        buckets.append(ReachBucket(servers=servers, idx=idx, valid=valid,
                                   width=width, key=int(key)))
    return ReachBuckets(buckets=tuple(buckets), bucket_of=bucket_of,
                        row_of=row_of, slot=slot, r_max=r_max)


@dataclass
class Scenario:
    dev: DeviceParams
    srv: ServerParams
    avail: np.ndarray            # (K, N) bool — device n can reach server i
    dist: np.ndarray             # (K, N) meters
    lp: LearningParams = field(default_factory=LearningParams)
    # Dynamic-scenario state (device churn / mobility). ``active`` marks the
    # devices currently present; ``None`` means everyone (the static case).
    # Positions and the reach radius are kept so perturb_scenario can drift
    # devices and recompute exactly the touched dist/avail columns.
    active: np.ndarray | None = None     # (N,) bool, None == all active
    dev_xy: np.ndarray | None = None     # (N, 2) meters
    srv_xy: np.ndarray | None = None     # (K, 2) meters
    reach_m: float | None = None
    # Per-edge admission capacity: server i can hold at most ``max_devices[i]``
    # active members (production edges have hard compute/memory/uplink caps;
    # the paper's eq. 17 model lets any reachable edge absorb everyone).
    # ``None`` = unlimited, the paper-faithful default. Capacities are
    # churn-invariant: perturb_scenario carries them unchanged and
    # diff_scenarios rejects scenarios whose caps differ.
    max_devices: np.ndarray | None = None  # (K,) int, None == no caps

    @property
    def n_devices(self) -> int:
        return self.dev.n_devices

    @property
    def n_servers(self) -> int:
        return self.srv.n_servers

    @property
    def active_mask(self) -> np.ndarray:
        """(N,) bool — always materialized, all-True when ``active`` unset."""
        if self.active is None:
            return np.ones(self.n_devices, dtype=bool)
        return np.asarray(self.active, dtype=bool)

    @property
    def eff_avail(self) -> np.ndarray:
        """Effective availability: reachability restricted to active devices
        (an inactive device can associate with no one)."""
        if self.active is None:
            return np.asarray(self.avail, dtype=bool)
        return np.asarray(self.avail, dtype=bool) & self.active_mask[None, :]

    @property
    def capacity(self) -> np.ndarray | None:
        """Validated (K,) int64 per-edge capacity, or ``None`` when the
        scenario is uncapacitated. The single normalization point every
        capacity consumer (engines, repair, admission) reads."""
        if self.max_devices is None:
            return None
        cap = np.asarray(self.max_devices, dtype=np.int64)
        if cap.shape != (self.n_servers,):
            raise ValueError(
                f"max_devices must have shape ({self.n_servers},), "
                f"got {cap.shape}")
        if (cap < 1).any():
            raise ValueError("max_devices entries must be >= 1")
        return cap


# ---------------------------------------------------------------------------
# Dynamic scenarios: seeded perturbations + incremental reach maintenance
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScenarioDelta:
    """Record of one :func:`perturb_scenario` step — everything an
    incremental consumer needs to patch static state instead of rebuilding.

    ``stale_servers`` is the conservative invalidation set for per-server
    caches keyed on the *scenario* (slot-index maps, gathered per-slot
    constants, toggle-cost rows): every server whose effective reachable set
    changed, plus every server reaching a moved device in the old or new
    scenario (distance-derived quantities may differ even when reach did
    not). Association-state invalidation (groups whose membership the warm
    start repairs) is the consumer's to add on top.
    """

    seed: int
    moved: np.ndarray          # (N,) bool — position (dist column) changed
    arrived: np.ndarray        # (N,) bool — inactive -> active
    departed: np.ndarray       # (N,) bool — active -> inactive
    avail_flips: np.ndarray    # (K, N) bool — raw reachability bits flipped
    eff_flips: np.ndarray      # (K, N) bool — effective (active-masked) flips
    stale_servers: np.ndarray  # (K,) bool — see docstring

    @property
    def touched_devices(self) -> np.ndarray:
        return (self.moved | self.arrived | self.departed
                | self.avail_flips.any(axis=0))


def perturb_scenario(sc: Scenario, *, seed: int, drift_m: float = 50.0,
                     move_frac: float = 0.1, flip_frac: float = 0.0,
                     depart_frac: float = 0.0, arrive_frac: float = 0.0
                     ) -> tuple[Scenario, ScenarioDelta]:
    """One seeded, deterministic churn step: device mobility (Gaussian
    position drift re-deriving the touched dist/avail columns), per-device
    reach flips (blockage: one random server bit per picked device), and
    arrivals/departures via the ``active`` mask.

    Device/server physical parameters (and hence every RA constant) are held
    fixed — in particular the per-device channel gain, whose shadowing draw
    dominates its within-area distance spread — so group costs change ONLY
    through membership and reachability. That is the invariant incremental
    consumers rely on: an unchanged group's cached cost stays valid across
    the delta.

    Fractions are of the eligible population (active for departures/moves/
    flips, inactive for arrivals). EVERY device — active or parked — is
    guaranteed at least its nearest server after the step (constraint 17e
    repair), so ``reach_index_map(new.avail, active=new.active)`` always
    succeeds AND the parked-slot rules (``nearest raw-reachable server``)
    stay well defined for inactive devices. This is the reach invariant the
    generators promise and the property tests pin: drift and reach flips can
    empty a device's row mid-step, but never in the returned scenario.
    Returns ``(new_scenario, delta)``; ``sc`` itself is not mutated.
    """
    if sc.dev_xy is None or sc.srv_xy is None or sc.reach_m is None:
        raise ValueError(
            "perturb_scenario needs positions and reach_m on the Scenario "
            "(rebuild it with make_scenario/make_large_scenario)")
    rng = np.random.default_rng(seed)
    n, k = sc.n_devices, sc.n_servers
    active_old = sc.active_mask
    avail_old = np.asarray(sc.avail, dtype=bool)

    def pick(mask: np.ndarray, frac: float) -> np.ndarray:
        cand = np.flatnonzero(mask)
        m = min(int(round(frac * cand.size)), cand.size)
        out = np.zeros(n, dtype=bool)
        if m:
            out[rng.choice(cand, size=m, replace=False)] = True
        return out

    departed = pick(active_old, depart_frac)
    arrived = pick(~active_old, arrive_frac)
    active_new = (active_old & ~departed) | arrived

    moved = pick(active_new, move_frac)
    dev_xy = np.asarray(sc.dev_xy, dtype=float).copy()
    dist = np.asarray(sc.dist, dtype=float).copy()
    avail = avail_old.copy()
    if moved.any():
        dev_xy[moved] += rng.normal(0.0, drift_m,
                                    size=(int(moved.sum()), 2))
        dist[:, moved] = np.linalg.norm(
            np.asarray(sc.srv_xy)[:, None, :] - dev_xy[None, moved, :],
            axis=-1)
        avail[:, moved] = dist[:, moved] <= sc.reach_m

    flipped = pick(active_new, flip_frac)
    if flipped.any():
        cols = np.flatnonzero(flipped)
        rows = rng.integers(0, k, cols.size)
        avail[rows, cols] = ~avail[rows, cols]

    # 17e repair over ALL devices: flips/moves only ever touch active
    # columns, but repairing inactive columns too keeps the all-device
    # reach invariant robust on hand-built scenarios (parked slots read
    # raw reach, so a zero row there would poison the repair paths)
    nearest = np.argmin(dist, axis=0)
    bad = ~avail.any(axis=0)
    avail[nearest[bad], bad] = True

    avail_flips, eff_flips, stale = _delta_flips(
        avail_old, active_old, avail, active_new, moved)

    sc_new = dataclasses.replace(sc, avail=avail, dist=dist,
                                 active=active_new, dev_xy=dev_xy)
    delta = ScenarioDelta(seed=seed, moved=moved, arrived=arrived,
                          departed=departed, avail_flips=avail_flips,
                          eff_flips=eff_flips, stale_servers=stale)
    return sc_new, delta


def _same_params(a, b) -> bool:
    """True when two parameter dataclasses hold equal arrays (identity
    short-circuits the common case: ``perturb_scenario`` carries the very
    same dev/srv objects across ticks)."""
    if a is b:
        return True
    return all(np.array_equal(np.asarray(getattr(a, f.name)),
                              np.asarray(getattr(b, f.name)))
               for f in dataclasses.fields(a))


def _delta_flips(avail_old: np.ndarray, active_old: np.ndarray,
                 avail_new: np.ndarray, active_new: np.ndarray,
                 moved: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The ONE derivation of a delta's ``(avail_flips, eff_flips,
    stale_servers)`` — shared by :func:`perturb_scenario` (single tick) and
    :func:`diff_scenarios` (multi-tick diff), so the conservative staleness
    rule incremental consumers rely on cannot diverge between the two:
    every server whose effective reachable set changed, plus every server
    reaching a moved device in the old or new scenario (distance-derived
    quantities may differ even when reach did not)."""
    avail_flips = avail_new != avail_old
    eff_flips = ((avail_new & active_new[None, :])
                 != (avail_old & active_old[None, :]))
    stale = eff_flips.any(axis=1)
    if moved.any():
        stale |= avail_old[:, moved].any(axis=1)
        stale |= avail_new[:, moved].any(axis=1)
    return avail_flips, eff_flips, stale


def diff_scenarios(sc_old: Scenario, sc_new: Scenario) -> ScenarioDelta:
    """Recover a :class:`ScenarioDelta` by diffing two same-shaped scenarios.

    This is the multi-tick composition the live training loop needs when the
    association engine re-solves less often than the scenario churns:
    ``perturb_scenario`` deltas describe single ticks, and replaying them one
    at a time would force one incremental re-solve per tick. Diffing the
    scenario at the last re-solve against the current one yields the single
    combined delta ``FastAssociationEngine.rerun_incremental`` expects —
    with the same conservative ``stale_servers`` semantics (servers whose
    effective reach changed, plus servers reaching a moved device in either
    scenario). A device that departed and returned between the endpoints
    cancels out, exactly as it should for cache invalidation purposes
    (``seed`` is -1: a diff has no generating seed).
    """
    if (sc_old.n_devices != sc_new.n_devices
            or sc_old.n_servers != sc_new.n_servers):
        raise ValueError("diff_scenarios requires same-shaped scenarios")
    caps_match = ((sc_old.max_devices is None) == (sc_new.max_devices is None)
                  and (sc_old.max_devices is None
                       or np.array_equal(np.asarray(sc_old.max_devices),
                                         np.asarray(sc_new.max_devices))))
    if not (_same_params(sc_old.dev, sc_new.dev)
            and _same_params(sc_old.srv, sc_new.srv)
            and sc_old.lp == sc_new.lp and caps_match):
        # caches keyed on RA constants survive a delta ONLY because device/
        # server/learning params (and per-edge caps) are churn-invariant;
        # diffing two unrelated scenarios would silently poison every
        # incremental consumer
        raise ValueError(
            "diff_scenarios requires churn-invariant device/server/learning "
            "parameters and capacities (only avail/dist/active/dev_xy may "
            "differ)")
    active_old = sc_old.active_mask
    active_new = sc_new.active_mask
    avail_old = np.asarray(sc_old.avail, dtype=bool)
    avail_new = np.asarray(sc_new.avail, dtype=bool)
    moved = (np.asarray(sc_old.dist) != np.asarray(sc_new.dist)).any(axis=0)
    arrived = active_new & ~active_old
    departed = active_old & ~active_new
    avail_flips, eff_flips, stale = _delta_flips(
        avail_old, active_old, avail_new, active_new, moved)
    return ScenarioDelta(seed=-1, moved=moved, arrived=arrived,
                         departed=departed, avail_flips=avail_flips,
                         eff_flips=eff_flips, stale_servers=stale)


@dataclass(frozen=True)
class DeviceClientBridge:
    """Index bridge between a Scenario's device axis and a federated
    dataset's client axis — the seam the live co-simulation crosses every
    round (``Scenario.active`` -> trainer ``client_mask``, device->server
    assignment -> per-client assignment).

    ``device_of[c]`` is the device backing client ``c``; ``client_of[n]`` is
    the client backed by device ``n`` (or -1 for a device with no client —
    legal when the scenario models more devices than the dataset has
    clients). The default bridge is the identity prefix."""

    device_of: np.ndarray   # (n_clients,) int32
    client_of: np.ndarray   # (n_devices,) int32, -1 = no client

    @property
    def n_clients(self) -> int:
        return int(self.device_of.shape[0])

    @property
    def n_devices(self) -> int:
        return int(self.client_of.shape[0])

    def client_mask(self, devices: np.ndarray) -> np.ndarray:
        """Map any device-axis boolean mask (``Scenario.active``, an arrival
        set, ...) onto the client axis; devices backing no client drop out."""
        return np.asarray(devices, dtype=bool)[self.device_of]

    def client_assignment(self, assignment: np.ndarray) -> np.ndarray:
        """Map a device->server assignment onto the client axis."""
        return np.asarray(assignment)[self.device_of]


def device_client_bridge(sc: Scenario, n_clients: int,
                         device_of: np.ndarray | None = None
                         ) -> DeviceClientBridge:
    """Build (and validate) the device<->client bridge for ``sc``.

    ``device_of`` defaults to the identity prefix ``arange(n_clients)`` —
    client ``c`` is device ``c`` — which requires ``n_clients <= N``. An
    explicit ``device_of`` may map clients to any distinct devices.
    """
    n = sc.n_devices
    if device_of is None:
        if n_clients > n:
            raise ValueError(
                f"dataset has {n_clients} clients but the scenario only "
                f"{n} devices; pass an explicit device_of mapping")
        device_of = np.arange(n_clients, dtype=np.int32)
    device_of = np.asarray(device_of, dtype=np.int32)
    if device_of.shape != (n_clients,):
        raise ValueError(f"device_of must have shape ({n_clients},)")
    if device_of.size and (device_of.min() < 0 or device_of.max() >= n):
        raise ValueError("device_of entries must be valid device indices")
    if np.unique(device_of).size != device_of.size:
        raise ValueError("device_of must map clients to distinct devices")
    client_of = np.full(n, -1, dtype=np.int32)
    client_of[device_of] = np.arange(n_clients, dtype=np.int32)
    return DeviceClientBridge(device_of=device_of, client_of=client_of)


def _changed_rows(eff: np.ndarray, row_sets: list[np.ndarray]) -> np.ndarray:
    """Servers whose stored reachable set (``row_sets[s]`` = ascending device
    ids) no longer matches ``eff[s]`` — the default delta detector when the
    caller has no :class:`ScenarioDelta` at hand."""
    out = np.zeros(eff.shape[0], dtype=bool)
    for s in range(eff.shape[0]):
        reach = np.flatnonzero(eff[s])
        out[s] = (reach.size != row_sets[s].size
                  or not np.array_equal(reach, row_sets[s]))
    return out


def update_reach_index(ri: ReachIndex, avail: np.ndarray, *,
                       active: np.ndarray | None = None,
                       changed_servers: np.ndarray | None = None
                       ) -> tuple[ReachIndex, bool]:
    """Incrementally patch a flat :class:`ReachIndex` across an availability
    delta: changed servers' idx/valid/slot rows are rewritten at the map's
    existing allocated width (kept even when the new max reach count is
    smaller, so compiled shapes downstream survive); if any server's reach
    count overflows the allocated width the map is rebuilt from scratch.

    Returns ``(new_map, rebuilt)``. ``ri`` is not mutated.
    """
    eff = np.asarray(avail, dtype=bool)
    if active is not None:
        eff = eff & np.asarray(active, dtype=bool)[None, :]
    k, n = eff.shape
    counts = eff.sum(axis=1)
    if k and int(counts.max()) > ri.r_max:
        return reach_index_map(avail, active=active), True
    if changed_servers is None:
        changed_servers = _changed_rows(
            eff, [ri.idx[s, ri.valid[s]] for s in range(k)])
    idx, valid, slot = ri.idx.copy(), ri.valid.copy(), ri.slot.copy()
    for s in np.flatnonzero(np.asarray(changed_servers, dtype=bool)):
        _fill_reach_row(np.flatnonzero(eff[s]), idx[s], valid[s], slot[s],
                        ri.r_max)
    return ReachIndex(idx=idx, valid=valid, slot=slot, r_max=ri.r_max), False


def update_reach_buckets(rbk: ReachBuckets, avail: np.ndarray, *,
                         active: np.ndarray | None = None,
                         changed_servers: np.ndarray | None = None
                         ) -> tuple[ReachBuckets, list]:
    """Incrementally maintain :class:`ReachBuckets` across an availability
    delta.

    A changed server whose reach count stays inside its bucket's binary
    magnitude (same ``ceil(log2(count))`` key) and allocated width R_b gets
    its idx/valid/slot rows patched; a server that overflows (key change, or
    count beyond R_b) forces a rebuild of every bucket it leaves or joins —
    and ONLY those. Untouched buckets keep their arrays, so per-bucket
    compiled shapes and cached per-row state survive small deltas. The
    out-of-reach sentinel only ever grows (``max(old r_max, new widths)``);
    when it grows, stale sentinel entries in unchanged slot rows are
    remapped, so ``slot < R_b`` tests stay sound everywhere.

    Returns ``(new_rbk, carry)``: ``carry[b]`` is the old bucket index whose
    (servers, width) layout new bucket ``b`` preserves — per-row caches
    indexed by that layout stay aligned — or ``None`` for rebuilt buckets.
    ``rbk`` is not mutated.
    """
    eff = np.asarray(avail, dtype=bool)
    if active is not None:
        eff = eff & np.asarray(active, dtype=bool)[None, :]
    k, n = eff.shape
    counts = eff.sum(axis=1)
    keys_new = np.array([max(int(c) - 1, 0).bit_length() for c in counts])
    if changed_servers is None:
        sets = [None] * k
        for b in rbk.buckets:
            for row, srv in enumerate(b.servers):
                sets[srv] = b.idx[row, b.valid[row]]
        changed_servers = _changed_rows(eff, sets)
    changed = np.flatnonzero(np.asarray(changed_servers, dtype=bool))

    rebuild_keys: set[int] = set()
    patch: list[int] = []
    for s in changed:
        bk = rbk.buckets[rbk.bucket_of[s]]
        if int(keys_new[s]) == bk.key and int(counts[s]) <= bk.width:
            patch.append(int(s))
        else:
            rebuild_keys.add(bk.key)
            rebuild_keys.add(int(keys_new[s]))

    members = {key: np.flatnonzero(keys_new == key).astype(np.int32)
               for key in rebuild_keys}
    new_widths = [max(int(counts[m].max()), 1)
                  for m in members.values() if m.size]
    sentinel = max([rbk.r_max] + new_widths)
    slot = rbk.slot.copy()
    if sentinel > rbk.r_max:
        # valid slots are always < their bucket width <= the old sentinel,
        # so entries equal to it are exactly the out-of-reach markers
        slot[slot == rbk.r_max] = sentinel

    def fill_rows(servers, width):
        idx = np.zeros((len(servers), width), dtype=np.int32)
        valid = np.zeros((len(servers), width), dtype=bool)
        for row, srv in enumerate(servers):
            _fill_reach_row(np.flatnonzero(eff[srv]), idx[row], valid[row],
                            slot[srv], sentinel)
        return idx, valid

    new_buckets: list[ReachBucket] = []
    carry: list = []
    for ob, bk in enumerate(rbk.buckets):
        if bk.key in rebuild_keys:
            srvs = members[bk.key]
            if srvs.size:
                idx, valid = fill_rows(srvs, max(int(counts[srvs].max()), 1))
                new_buckets.append(ReachBucket(
                    servers=srvs, idx=idx, valid=valid,
                    width=idx.shape[1], key=bk.key))
                carry.append(None)
            continue
        in_bucket = [s for s in patch if rbk.bucket_of[s] == ob]
        if in_bucket:
            idx, valid = bk.idx.copy(), bk.valid.copy()
            for s in in_bucket:
                row = rbk.row_of[s]
                _fill_reach_row(np.flatnonzero(eff[s]), idx[row],
                                valid[row], slot[s], sentinel)
            bk = ReachBucket(servers=bk.servers, idx=idx, valid=valid,
                             width=bk.width, key=bk.key)
        new_buckets.append(bk)
        carry.append(ob)
    existing = {b.key for b in rbk.buckets}
    for key in sorted(rebuild_keys - existing):
        srvs = members[key]
        if srvs.size:
            idx, valid = fill_rows(srvs, max(int(counts[srvs].max()), 1))
            new_buckets.append(ReachBucket(servers=srvs, idx=idx, valid=valid,
                                           width=idx.shape[1], key=key))
            carry.append(None)

    bucket_of = np.zeros(k, dtype=np.int32)
    row_of = np.zeros(k, dtype=np.int32)
    for b, bk in enumerate(new_buckets):
        bucket_of[bk.servers] = b
        row_of[bk.servers] = np.arange(bk.servers.size, dtype=np.int32)
    return ReachBuckets(buckets=tuple(new_buckets), bucket_of=bucket_of,
                        row_of=row_of, slot=slot, r_max=sentinel), carry


def pairwise_dist(srv_xy: np.ndarray, dev_xy: np.ndarray, *,
                  chunk: int = 16_384) -> np.ndarray:
    """(K, N) server-device distances, chunked along the device axis.

    The obvious broadcast ``norm(srv_xy[:, None] - dev_xy[None], axis=-1)``
    materializes a (K, N, 2) float64 intermediate — ~800 MB at K=500 /
    N=100k — before reducing; chunking caps the intermediate at
    (K, chunk, 2) while writing into the one (K, N) output that is needed
    anyway. Chunk boundaries do not change any element's arithmetic, so the
    result is bit-identical to the dense broadcast.
    """
    srv_xy = np.asarray(srv_xy, dtype=float)
    dev_xy = np.asarray(dev_xy, dtype=float)
    k, n = srv_xy.shape[0], dev_xy.shape[0]
    out = np.empty((k, n), dtype=np.float64)
    for lo in range(0, max(n, 1), chunk):
        sl = slice(lo, min(lo + chunk, n))
        out[:, sl] = np.linalg.norm(
            srv_xy[:, None, :] - dev_xy[None, sl, :], axis=-1)
    return out


def channel_gain_from_distance(dist_m: np.ndarray) -> np.ndarray:
    """h = 10^(-PL/10), PL = 128.1 + 37.6 log10(d_km)."""
    d_km = np.maximum(dist_m, 1.0) / 1000.0
    pl_db = 128.1 + 37.6 * np.log10(d_km)
    return 10.0 ** (-pl_db / 10.0)


def make_scenario(n_devices: int, n_servers: int, *, seed: int = 0,
                  area_m: float = 500.0, reach_m: float = 10_000.0,
                  cap_slack: float | None = None,
                  lp: LearningParams | None = None) -> Scenario:
    """Sample a random scenario with Table II parameters.

    ``reach_m`` bounds which servers a device may associate with (N_i in the
    paper); the default makes every server reachable, matching the paper's
    fully-dense evaluation (availability is then only distance-ranked).
    ``cap_slack`` (optional) generates per-edge ``max_devices`` caps sized
    ``ceil(cap_slack * nearest-count)`` — see :func:`_capacities`.
    """
    rng = np.random.default_rng(seed)
    dev_xy = rng.uniform(0.0, area_m, size=(n_devices, 2))
    srv_xy = rng.uniform(0.0, area_m, size=(n_servers, 2))
    return _assemble(rng, dev_xy, srv_xy, reach_m, lp, cap_slack)


def make_large_scenario(n_devices: int, n_servers: int, *, seed: int = 0,
                        area_m: float | None = None,
                        reach_m: float | None = None,
                        spread_m: float = 120.0,
                        cap_slack: float | None = None,
                        lp: LearningParams | None = None) -> Scenario:
    """Cluster-structured scenario for the large regimes the association
    scaling benchmarks exercise — construction is memory-safe up to
    N~100k / K~500 (distances are computed in device-axis chunks, never
    materializing a (K, N, 2) intermediate).

    Unlike :func:`make_scenario`'s fixed 500m box, the area grows with the
    server count (constant server density), devices drop as Gaussian clusters
    of width ``spread_m`` around a random anchor server, and ``reach_m``
    defaults to a *restricted* radius so availability is sparse — each device
    can reach only its nearby handful of servers, the realistic multi-cell
    regime (every device is still guaranteed its nearest server). At the
    50k+ scales, tighten ``spread_m`` (e.g. 60) so per-server reach counts —
    and with them the sweep's toggle-cache width — stay bounded as N grows.
    ``cap_slack`` generates binding-by-construction per-edge caps; ``None``
    (default) keeps the paper's uncapacitated model, bit-identical to
    previous releases.
    """
    rng = np.random.default_rng(seed)
    area = area_m if area_m is not None else 500.0 * np.sqrt(n_servers / 5.0)
    reach = reach_m if reach_m is not None else 3.0 * spread_m
    srv_xy = rng.uniform(0.0, area, size=(n_servers, 2))
    anchor = rng.integers(0, n_servers, n_devices)
    dev_xy = np.clip(srv_xy[anchor]
                     + rng.normal(0.0, spread_m, size=(n_devices, 2)),
                     0.0, area)
    return _assemble(rng, dev_xy, srv_xy, reach, lp, cap_slack)


def _capacities(dist: np.ndarray, cap_slack: float) -> np.ndarray:
    """Per-edge ``max_devices`` sized from the nearest-server load profile.

    Server ``j`` gets ``max(1, ceil(cap_slack * |{i : nearest(i)=j}|))``
    slots. ``cap_slack`` slightly above 1.0 leaves headroom over the
    all-nearest assignment (caps rarely bind); below 1.0 forces spill onto
    second-choice edges (caps bind by construction). Deterministic in the
    geometry — consumes NO rng draws, so adding caps to a generator call
    never shifts the sampled device/server parameters.
    """
    if cap_slack <= 0.0:
        raise ValueError(f"cap_slack must be > 0, got {cap_slack}")
    nearest_count = np.bincount(np.argmin(dist, axis=0),
                                minlength=dist.shape[0])
    return np.maximum(1, np.ceil(cap_slack * nearest_count)).astype(np.int32)


def _assemble(rng: np.random.Generator, dev_xy: np.ndarray,
              srv_xy: np.ndarray, reach_m: float,
              lp: LearningParams | None,
              cap_slack: float | None = None) -> Scenario:
    """Draw Table II device/server parameters for given node positions."""
    f32 = np.float32
    n_devices = dev_xy.shape[0]
    n_servers = srv_xy.shape[0]
    dist = pairwise_dist(srv_xy, dev_xy)

    data_bits = rng.uniform(5e6, 10e6, n_devices) * 8.0          # 5-10 MB
    density = rng.uniform(30.0, 100.0, n_devices)                # cycle/bit
    # Power-law client sample counts (non-IID sizing per [20]); used only as
    # aggregation weights |D_n| — the physical compute load uses data_bits.
    samples = np.floor(rng.pareto(2.0, n_devices) * 200 + 50)

    # Per-device channel gain to its geometrically nearest server. The
    # within-area gain spread is modest, so a single h_n per device (as the
    # paper's Table I implies) is a faithful simplification.
    nearest = np.argmin(dist, axis=0)
    h = channel_gain_from_distance(dist[nearest, np.arange(n_devices)])
    h *= rng.lognormal(0.0, 0.5, n_devices)                      # shadowing

    dev = DeviceParams(
        cycles_per_iter=(density * data_bits).astype(f32),
        data_samples=samples.astype(f32),
        model_nats=np.full(n_devices, 25_000.0, f32),
        tx_power=np.full(n_devices, 0.2, f32),
        channel_gain=h.astype(f32),
        alpha=np.full(n_devices, 2e-28, f32),
        f_min=np.full(n_devices, 1e9, f32),
        f_max=np.full(n_devices, 10e9, f32),
    )
    srv = ServerParams(
        bandwidth=np.full(n_servers, 10e6, f32),
        noise=np.full(n_servers, 1e-8, f32),
        cloud_rate=rng.uniform(0.5e5, 1.5e5, n_servers).astype(f32),
        cloud_power=np.full(n_servers, 1.0, f32),
        cloud_nats=np.full(n_servers, 25_000.0, f32),
    )
    avail = dist <= reach_m
    # Constraint (17e) requires every device to be associable somewhere.
    unreachable = ~avail.any(axis=0)
    avail[nearest[unreachable], unreachable] = True

    return Scenario(dev=dev, srv=srv, avail=avail, dist=dist,
                    lp=lp or LearningParams(),
                    dev_xy=dev_xy.copy(), srv_xy=srv_xy.copy(),
                    reach_m=float(reach_m),
                    max_devices=(None if cap_slack is None
                                 else _capacities(dist, cap_slack)))
