"""Random HFEL scenario generation following the paper's Table II.

Devices and edge servers are dropped uniformly in a 500m x 500m area; the
channel gain follows the standard cellular path-loss model
``PL(dB) = 128.1 + 37.6 log10(d_km)`` (the paper cites [17] for the channel
set-up). Table II values:

  Edge bandwidth             10 MHz
  Device transmit power      200 mW
  Device CPU frequency       [1, 10] GHz
  Processing density         [30, 100] cycle/bit
  Background noise           1e-8 W
  Device training size       [5, 10] MB
  Updated model size         25000 nats
  Capacitance coefficient    2e-28
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.cost_model import DeviceParams, LearningParams, ServerParams


@dataclass(frozen=True)
class ReachIndex:
    """Static per-server compaction maps of a (K, N) availability matrix.

    ``idx[k, r]`` is the device index occupying reachable slot ``r`` of
    server ``k`` (devices in ascending order, padded with 0 past the server's
    reach count); ``valid[k, r]`` marks real slots; ``slot[k, n]`` inverts the
    map (slot of device ``n`` at server ``k``, or ``r_max`` when ``n`` is out
    of reach — a deliberate out-of-range sentinel so one-hot encodings of an
    invalid slot are all-zero). ``r_max`` is the widest reach count, i.e. the
    compacted buffer width shared by all servers.
    """

    idx: np.ndarray        # (K, R) int32
    valid: np.ndarray      # (K, R) bool
    slot: np.ndarray       # (K, N) int32, r_max == "unreachable"
    r_max: int

    @property
    def density(self) -> float:
        return float(self.valid.mean())

    @property
    def padded_fraction(self) -> float:
        """Fraction of compacted slots that are padding (wasted sweep work)."""
        return 1.0 - self.density


@dataclass(frozen=True)
class ReachBucket:
    """One width-bucket of :class:`ReachBuckets`: the servers whose reach
    count shares a binary magnitude, compacted at that bucket's own width."""

    servers: np.ndarray    # (K_b,) int32 global server ids
    idx: np.ndarray        # (K_b, R_b) int32 device per slot (0-padded)
    valid: np.ndarray      # (K_b, R_b) bool — real slots
    width: int             # R_b = widest reach count in this bucket


@dataclass(frozen=True)
class ReachBuckets:
    """Adaptive-width compaction maps: servers grouped into binary buckets by
    reach count (same power-of-two scheme as ``GroupSolver.solve_batch``'s
    chunking), each bucket compacted to its own slot width R_b instead of
    every server padding to the global max R. ``slot``/``bucket_of``/
    ``row_of`` locate any (server, device) pair: device ``n`` lives at slot
    ``slot[k, n]`` of row ``row_of[k]`` in bucket ``bucket_of[k]`` (slot
    ``r_max`` is the shared out-of-reach sentinel — it is >= every bucket
    width, so per-bucket ``slot < R_b`` tests reject it)."""

    buckets: tuple[ReachBucket, ...]
    bucket_of: np.ndarray  # (K,) int32
    row_of: np.ndarray     # (K,) int32 — row within the owning bucket
    slot: np.ndarray       # (K, N) int32, r_max == "unreachable"
    r_max: int

    @property
    def padded_fraction(self) -> float:
        total = sum(b.idx.size for b in self.buckets)
        real = sum(int(b.valid.sum()) for b in self.buckets)
        return 1.0 - real / max(total, 1)


def reach_index_map(avail: np.ndarray, *, bucketed: bool = False):
    """Compute the compacted reachable-set index maps of ``avail`` (K, N).

    The fused candidate sweeps in :mod:`repro.core.assoc_fast` run in this
    compacted (K, R) slot space: with sparse availability R << N, so both the
    number of candidate groups per refresh and the vector width of every
    group solve shrink by the reach density. Every server must reach at least
    one device only if it is ever used; zero-reach *devices* are rejected
    because they cannot be associated anywhere (constraint 17e).

    ``bucketed=True`` returns :class:`ReachBuckets` instead: servers are
    grouped by ``ceil(log2(reach_count))`` and each bucket is compacted at
    its own width, so one dense-reach server no longer pads every other
    server's row to the global max (see ``padded_fraction``).
    """
    avail = np.asarray(avail, dtype=bool)
    if not avail.any(axis=0).all():
        raise ValueError("every device must reach at least one server")
    k, n = avail.shape
    counts = avail.sum(axis=1)
    r_max = int(counts.max()) if k else 0

    def fill(servers, width, slot):
        """Fill one group's (idx, valid) rows and its servers' slot-map rows
        — the ONE place slot numbering / padding semantics live."""
        idx = np.zeros((len(servers), width), dtype=np.int32)
        valid = np.zeros((len(servers), width), dtype=bool)
        for row, srv in enumerate(servers):
            reach = np.flatnonzero(avail[srv])
            idx[row, :reach.size] = reach
            valid[row, :reach.size] = True
            slot[srv, reach] = np.arange(reach.size, dtype=np.int32)
        return idx, valid

    slot = np.full((k, n), r_max, dtype=np.int32)
    if not bucketed:
        idx, valid = fill(range(k), r_max, slot)
        return ReachIndex(idx=idx, valid=valid, slot=slot, r_max=r_max)

    # binary bucketing: key = ceil(log2(count)); a zero-reach server (legal
    # when it is simply never used) joins the narrowest bucket
    keys = np.array([max(int(c) - 1, 0).bit_length() for c in counts])
    buckets = []
    bucket_of = np.zeros(k, dtype=np.int32)
    row_of = np.zeros(k, dtype=np.int32)
    for b, key in enumerate(sorted(set(keys.tolist()))):
        servers = np.flatnonzero(keys == key).astype(np.int32)
        width = max(int(counts[servers].max()), 1)
        idx, valid = fill(servers, width, slot)
        bucket_of[servers] = b
        row_of[servers] = np.arange(servers.size, dtype=np.int32)
        buckets.append(ReachBucket(servers=servers, idx=idx, valid=valid,
                                   width=width))
    return ReachBuckets(buckets=tuple(buckets), bucket_of=bucket_of,
                        row_of=row_of, slot=slot, r_max=r_max)


@dataclass
class Scenario:
    dev: DeviceParams
    srv: ServerParams
    avail: np.ndarray            # (K, N) bool — device n can reach server i
    dist: np.ndarray             # (K, N) meters
    lp: LearningParams = field(default_factory=LearningParams)

    @property
    def n_devices(self) -> int:
        return self.dev.n_devices

    @property
    def n_servers(self) -> int:
        return self.srv.n_servers


def channel_gain_from_distance(dist_m: np.ndarray) -> np.ndarray:
    """h = 10^(-PL/10), PL = 128.1 + 37.6 log10(d_km)."""
    d_km = np.maximum(dist_m, 1.0) / 1000.0
    pl_db = 128.1 + 37.6 * np.log10(d_km)
    return 10.0 ** (-pl_db / 10.0)


def make_scenario(n_devices: int, n_servers: int, *, seed: int = 0,
                  area_m: float = 500.0, reach_m: float = 10_000.0,
                  lp: LearningParams | None = None) -> Scenario:
    """Sample a random scenario with Table II parameters.

    ``reach_m`` bounds which servers a device may associate with (N_i in the
    paper); the default makes every server reachable, matching the paper's
    fully-dense evaluation (availability is then only distance-ranked).
    """
    rng = np.random.default_rng(seed)
    dev_xy = rng.uniform(0.0, area_m, size=(n_devices, 2))
    srv_xy = rng.uniform(0.0, area_m, size=(n_servers, 2))
    return _assemble(rng, dev_xy, srv_xy, reach_m, lp)


def make_large_scenario(n_devices: int, n_servers: int, *, seed: int = 0,
                        area_m: float | None = None,
                        reach_m: float | None = None,
                        spread_m: float = 120.0,
                        lp: LearningParams | None = None) -> Scenario:
    """Cluster-structured scenario for the large regimes (up to N~2000, K~50)
    the association scaling benchmarks exercise.

    Unlike :func:`make_scenario`'s fixed 500m box, the area grows with the
    server count (constant server density), devices drop as Gaussian clusters
    of width ``spread_m`` around a random anchor server, and ``reach_m``
    defaults to a *restricted* radius so availability is sparse — each device
    can reach only its nearby handful of servers, the realistic multi-cell
    regime (every device is still guaranteed its nearest server).
    """
    rng = np.random.default_rng(seed)
    area = area_m if area_m is not None else 500.0 * np.sqrt(n_servers / 5.0)
    reach = reach_m if reach_m is not None else 3.0 * spread_m
    srv_xy = rng.uniform(0.0, area, size=(n_servers, 2))
    anchor = rng.integers(0, n_servers, n_devices)
    dev_xy = np.clip(srv_xy[anchor]
                     + rng.normal(0.0, spread_m, size=(n_devices, 2)),
                     0.0, area)
    return _assemble(rng, dev_xy, srv_xy, reach, lp)


def _assemble(rng: np.random.Generator, dev_xy: np.ndarray,
              srv_xy: np.ndarray, reach_m: float,
              lp: LearningParams | None) -> Scenario:
    """Draw Table II device/server parameters for given node positions."""
    f32 = np.float32
    n_devices = dev_xy.shape[0]
    n_servers = srv_xy.shape[0]
    dist = np.linalg.norm(srv_xy[:, None, :] - dev_xy[None, :, :], axis=-1)

    data_bits = rng.uniform(5e6, 10e6, n_devices) * 8.0          # 5-10 MB
    density = rng.uniform(30.0, 100.0, n_devices)                # cycle/bit
    # Power-law client sample counts (non-IID sizing per [20]); used only as
    # aggregation weights |D_n| — the physical compute load uses data_bits.
    samples = np.floor(rng.pareto(2.0, n_devices) * 200 + 50)

    # Per-device channel gain to its geometrically nearest server. The
    # within-area gain spread is modest, so a single h_n per device (as the
    # paper's Table I implies) is a faithful simplification.
    nearest = np.argmin(dist, axis=0)
    h = channel_gain_from_distance(dist[nearest, np.arange(n_devices)])
    h *= rng.lognormal(0.0, 0.5, n_devices)                      # shadowing

    dev = DeviceParams(
        cycles_per_iter=(density * data_bits).astype(f32),
        data_samples=samples.astype(f32),
        model_nats=np.full(n_devices, 25_000.0, f32),
        tx_power=np.full(n_devices, 0.2, f32),
        channel_gain=h.astype(f32),
        alpha=np.full(n_devices, 2e-28, f32),
        f_min=np.full(n_devices, 1e9, f32),
        f_max=np.full(n_devices, 10e9, f32),
    )
    srv = ServerParams(
        bandwidth=np.full(n_servers, 10e6, f32),
        noise=np.full(n_servers, 1e-8, f32),
        cloud_rate=rng.uniform(0.5e5, 1.5e5, n_servers).astype(f32),
        cloud_power=np.full(n_servers, 1.0, f32),
        cloud_nats=np.full(n_servers, 25_000.0, f32),
    )
    avail = dist <= reach_m
    # Constraint (17e) requires every device to be associable somewhere.
    unreachable = ~avail.any(axis=0)
    avail[nearest[unreachable], unreachable] = True

    return Scenario(dev=dev, srv=srv, avail=avail, dist=dist,
                    lp=lp or LearningParams())
