"""Edge association across multiple edge servers — paper Section IV.

Implements Algorithm 3 (device *transferring* and *exchanging* adjustments
iterated to a stable system point, Defs. 4-6 / Thm. 3) plus a beyond-paper
batched variant that evaluates every candidate transfer of a round in one
vmapped solve and applies the steepest permitted move.

Permission rules
----------------
The paper's Definition 3 ("pareto order") literally requires every changed
group's utility not to decrease — but moving a device INTO a group always
adds cost to it (every added device contributes a positive a_n/beta term),
so under the strict reading no transfer is ever permitted, contradicting the
paper's own Figs. 3-6.  We therefore implement both readings:

* ``permission="utilitarian"`` (default, matches the paper's observed
  behaviour and its global objective (17)): an adjustment is permitted iff
  the system-wide cost strictly decreases.
* ``permission="pareto"`` (strict Definition 3): additionally no involved
  server's cost may increase.

Global surrogate objective
--------------------------
Following the paper's decomposition v(DS) = sum_i v(S_i), the association
optimizes  sum_i [ C_i + 1{S_i != {}} * (lambda_e E^cloud_i +
lambda_t T^cloud_i) ]  — the sum-of-servers surrogate of (17) (the true
delay term is a max over servers; both are reported).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import resource_allocation as ra
from repro.core.cost_model import (DeviceParams, LearningParams, RAConstants,
                                   ServerParams, cloud_delay, cloud_energy,
                                   global_cost, ra_constants)
from repro.core.scenario import Scenario


# ---------------------------------------------------------------------------
# Batched per-server group solver with pluggable schemes
# ---------------------------------------------------------------------------

SCHEME_KINDS = ("optimal", "fast", "paper", "comp_only", "comm_only",
                "uniform", "proportional")


def _fixed_eval(c: RAConstants, mask, beta, random_f) -> ra.RASolution:
    """Evaluate (18) at a fixed (random-f, given-beta) point — no optimization."""
    from repro.core.cost_model import ra_objective
    f = jnp.clip(random_f, c.f_min, c.f_max)
    safe_beta = jnp.where(mask, jnp.maximum(beta, 1e-12), 1.0)
    cost = jnp.where(jnp.any(mask), ra_objective(c, mask, f, safe_beta), 0.0)
    deadline = jnp.max(jnp.where(mask, c.d / safe_beta + c.e / f, 0.0))
    return ra.RASolution(f=f, beta=jnp.where(mask, beta, 0.0),
                         cost=cost, deadline=deadline)


def solve_group(kind: str, c: RAConstants, mask, *, random_f=None,
                inv_dist_row=None, profile: str = "default") -> ra.RASolution:
    """Pure single-group RA dispatch shared by :class:`GroupSolver` and the
    device-resident engine in :mod:`repro.core.assoc_fast`.

    ``c`` holds ONE server's constants; ``mask`` selects the group members.
    ``random_f`` / ``inv_dist_row`` supply the fixed decisions the degenerate
    §V.A schemes need; ``profile`` picks a :data:`ra.SCREEN_PROFILES` preset
    for the ``fast`` kind (the others are profile-free).
    """
    n_active = jnp.maximum(jnp.sum(mask), 1)
    if kind == "fast":
        return ra.solve_fixed_point(c, mask, **ra.SCREEN_PROFILES[profile])
    if kind in ("optimal", "paper"):
        fn = {"optimal": ra.solve_exact, "paper": ra.solve_paper}[kind]
        return fn(c, mask)
    if kind == "comp_only":
        beta = jnp.where(mask, 1.0 / n_active, 0.0)
        return ra.optimize_f_given_beta(c, mask, beta)
    if kind == "comm_only":
        return ra.optimize_beta_given_f(c, mask, random_f)
    if kind == "uniform":
        beta = jnp.where(mask, 1.0 / n_active, 0.0)
        return _fixed_eval(c, mask, beta, random_f)
    if kind == "proportional":
        score = jnp.where(mask, inv_dist_row, 0.0)
        beta = score / jnp.maximum(jnp.sum(score), 1e-12)
        return _fixed_eval(c, mask, beta, random_f)
    raise ValueError(kind)


@partial(jax.jit, static_argnames=("kind", "profile"))
# hfellint: disable=HFEL006 -- consts/inv_dist are cache-resident constants
def _solve_batch_pure(consts, random_f, inv_dist, server_ids, masks, *,
                      kind, profile):
    """Module-level vmapped group solve so the jit cache is shared across
    every GroupSolver instance (per-instance jits used to recompile each
    bucket size for each new engine)."""

    def one(s, m):
        c = jax.tree.map(lambda x: x[s], consts)
        return solve_group(kind, c, m, random_f=random_f,
                           inv_dist_row=inv_dist[s], profile=profile)

    return jax.vmap(one)(server_ids, masks)


class GroupSolver:
    """Caches per-server RA constants and solves (server, member-mask) groups.

    ``kind`` selects the resource-allocation scheme of §V.A:
      optimal      — solve_exact            (full joint optimization)
      fast         — solve_fixed_point      (screening-grade joint opt.)
      paper        — solve_paper            (Algorithm 2 faithful)
      comp_only    — optimal f, uniform beta
      comm_only    — optimal beta, random fixed f
      uniform      — uniform beta, random fixed f
      proportional — beta inversely proportional to distance, random fixed f
    """

    def __init__(self, sc: Scenario, kind: str = "fast", *, seed: int = 0,
                 profile: str = "default"):
        assert kind in SCHEME_KINDS, kind
        assert profile in ra.SCREEN_PROFILES, profile
        self.sc = sc
        self.kind = kind
        self.profile = profile
        n, k = sc.n_devices, sc.n_servers
        # batched constants: leading axis = server
        self.consts = jax.vmap(
            lambda bw, n0: ra_constants(sc.dev, bw, n0, sc.lp)
        )(sc.srv.bandwidth, sc.srv.noise)
        rng = np.random.default_rng(seed)
        fmin = np.asarray(sc.dev.f_min)
        fmax = np.asarray(sc.dev.f_max)
        self.random_f = jnp.asarray(
            rng.uniform(fmin, fmax).astype(np.float32))
        # inverse-distance scores per (server, device) for "proportional"
        inv = 1.0 / np.maximum(np.asarray(sc.dist), 1.0)
        self.inv_dist = jnp.asarray(inv.astype(np.float32))

    def with_profile(self, profile: str) -> "GroupSolver":
        """A view of this solver at another iteration profile; the batched
        constants and fixed random draws are shared, not recomputed."""
        assert profile in ra.SCREEN_PROFILES, profile
        if profile == self.profile:
            return self
        clone = object.__new__(GroupSolver)
        clone.__dict__.update(self.__dict__)
        clone.profile = profile
        return clone

    def _consts_at(self, i) -> RAConstants:
        return jax.tree.map(lambda x: x[i], self.consts)

    def _solve_one(self, server_idx, mask):
        return solve_group(self.kind, self._consts_at(server_idx), mask,
                           random_f=self.random_f,
                           inv_dist_row=self.inv_dist[server_idx],
                           profile=self.profile)

    def _batch_fn(self, server_ids, masks):
        return _solve_batch_pure(self.consts, self.random_f, self.inv_dist,
                                 server_ids, masks, kind=self.kind,
                                 profile=self.profile)

    def solve_batch(self, server_ids: jnp.ndarray, masks: jnp.ndarray) -> ra.RASolution:
        """Solve C candidate groups at once: server_ids (C,), masks (C, N).

        The batch is split into power-of-two chunks (binary decomposition of
        C) so the vmapped solver still compiles once per bucket size, but no
        all-zero padding rows burn full RA iterations — the old next-pow2
        padding wasted up to 2x solves on odd batch sizes.
        """
        server_ids = np.asarray(server_ids)
        masks = np.asarray(masks)
        c = server_ids.shape[0]
        if c == 0:
            sol = self._batch_fn(jnp.zeros(1, np.int64),
                                 jnp.zeros((1, masks.shape[1]), bool))
            return jax.tree.map(lambda x: x[:0], sol)
        chunks = []
        off = 0
        while off < c:
            size = 1 << ((c - off).bit_length() - 1)   # largest pow2 <= rest
            chunks.append(self._batch_fn(
                jnp.asarray(server_ids[off:off + size]),
                jnp.asarray(masks[off:off + size])))
            off += size
        if len(chunks) == 1:
            return chunks[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs), *chunks)


# ---------------------------------------------------------------------------
# Guarded feasibility helpers (shared by host + device engines + live loop)
# ---------------------------------------------------------------------------

class NoFeasibleServerError(RuntimeError):
    """Raised when a device has no reachable (and, under capacities, no
    admitting) server — the replacement for the old silent all-``inf``
    ``argmin``, which parked such devices on server 0 with no signal.

    ``devices`` lists the offending device indices so callers (e.g. the
    live admission loop) can demote or queue them instead of crashing.
    """

    def __init__(self, devices, reason: str = "no feasible server"):
        self.devices = np.atleast_1d(np.asarray(devices, dtype=np.int64))
        super().__init__(f"{reason} for device(s) {self.devices.tolist()}")


def nearest_feasible(dist: np.ndarray, feasible: np.ndarray, *,
                     need: np.ndarray | None = None) -> np.ndarray:
    """Nearest feasible server per device, with the zero-feasible case made
    EXPLICIT instead of numpy's silent ``argmin(all-inf column) == 0``.

    ``dist``/``feasible`` are (K, N); returns (N,) int64. Devices outside
    the ``need`` mask (default: all devices need a slot) are exempt from
    the check — callers overwrite those slots — while a needed device with
    an empty feasible column raises :class:`NoFeasibleServerError`.
    """
    feasible = np.asarray(feasible, dtype=bool)
    any_ok = feasible.any(axis=0)
    satisfied = any_ok if need is None else any_ok | ~np.asarray(need, bool)
    if not satisfied.all():
        raise NoFeasibleServerError(np.flatnonzero(~satisfied))
    return np.argmin(np.where(feasible, np.asarray(dist), np.inf), axis=0)


def parked_slots(sc: Scenario) -> np.ndarray:
    """Deterministic bookkeeping slot per device: nearest raw-reachable
    server, falling back EXPLICITLY to the globally nearest server on a
    zero-raw-reach column (possible only on hand-built scenarios — the
    generators repair raw reach for every device). Parked slots carry no
    cost and belong to no group; they only keep assignment arrays
    fixed-size, so reach there is a nicety, not a constraint.
    """
    dist = np.asarray(sc.dist)
    raw = np.asarray(sc.avail, dtype=bool)
    slots = np.argmin(np.where(raw, dist, np.inf), axis=0)
    orphan = ~raw.any(axis=0)
    if orphan.any():
        slots[orphan] = np.argmin(dist[:, orphan], axis=0)
    return slots


def greedy_admission(dist: np.ndarray, feasible: np.ndarray,
                     load: np.ndarray, cap: np.ndarray,
                     devices: np.ndarray) -> np.ndarray:
    """Sequential nearest-feasible placement under per-edge caps.

    Walks ``devices`` in the given order; each takes the nearest server
    among ``feasible[:, d] & (load < cap)`` and bumps that server's
    ``load`` (mutated in place). Returns placements aligned with
    ``devices``, ``-1`` marking devices NO server could admit — the caller
    decides whether that is an error (solver init/repair) or an
    overflow-queue entry (the live admission loop). O(K) vectorized per
    device with no solver involvement: this IS the streaming admission
    primitive.
    """
    dist = np.asarray(dist)
    feasible = np.asarray(feasible, dtype=bool)
    devices = np.asarray(devices, dtype=np.int64)
    out = np.full(devices.shape[0], -1, dtype=np.int64)
    for r, d in enumerate(devices):
        cand = feasible[:, d] & (load < cap)
        if not cand.any():
            continue
        j = int(np.argmin(np.where(cand, dist[:, d], np.inf)))
        out[r] = j
        load[j] += 1
    return out


# ---------------------------------------------------------------------------
# Association state and result
# ---------------------------------------------------------------------------

def initial_assignment(sc: Scenario, avail: np.ndarray, rng,
                       init: str = "nearest") -> np.ndarray:
    """Initial association (§II.C / Algorithm 3 line 2), shared by the host
    and device engines so 'random' inits stay draw-for-draw identical.

    On churn scenarios (``sc.active`` set) only active devices draw a real
    placement from ``avail`` (normally the *effective* availability);
    inactive devices get a deterministic parked slot (:func:`parked_slots`)
    that exists purely so the assignment array stays fixed-size (they
    belong to no group and cost nothing). An active device with an empty
    ``avail`` column raises :class:`NoFeasibleServerError` instead of the
    old silent server-0 fallback. With ``sc.capacity`` set, 'nearest'
    becomes greedy sequential admission in device order and 'random' draws
    restrict to servers with headroom at the device's turn (draw-for-draw
    identical to the uncapacitated path whenever caps never bind).
    """
    active = sc.active_mask
    cap = sc.capacity
    avail = np.asarray(avail, dtype=bool)
    out = np.empty(sc.n_devices, dtype=np.int64)
    out[~active] = parked_slots(sc)[~active]
    act = np.flatnonzero(active)
    if init == "nearest":
        if cap is None:
            out[active] = nearest_feasible(sc.dist, avail,
                                           need=active)[active]
            return out
        load = np.zeros(sc.n_servers, dtype=np.int64)
        placed = greedy_admission(sc.dist, avail, load, cap, act)
        if (placed < 0).any():
            raise NoFeasibleServerError(act[placed < 0],
                                        "no admitting server")
        out[act] = placed
        return out
    if init == "random":
        load = np.zeros(sc.n_servers, dtype=np.int64)
        for d in act:
            ok = avail[:, d] if cap is None else avail[:, d] & (load < cap)
            choices = np.flatnonzero(ok)
            if choices.size == 0:
                raise NoFeasibleServerError(
                    [d], "no feasible server" if cap is None
                    else "no admitting server")
            out[d] = rng.choice(choices)
            load[out[d]] += 1
        return out
    raise ValueError(init)


@dataclass
class AssociationResult:
    assignment: np.ndarray            # (N,) device -> server
    f: np.ndarray                     # (N,)
    beta: np.ndarray                  # (N,)
    server_cost: np.ndarray           # (K,) C_i at the stable point
    total_cost: float                 # surrogate objective (see module doc)
    true_energy: float                # eq. (15)
    true_delay: float                 # eq. (16)
    true_cost: float                  # eq. (17)
    n_adjustments: int                # applied permitted adjustments (Figs 5-6)
    n_rounds: int
    cost_trace: list = field(default_factory=list)


class AssociationEngine:
    """Runs initialization + adjustment iterations to a stable system point."""

    def __init__(self, sc: Scenario, *, kind: str = "fast",
                 permission: str = "utilitarian", min_residual_group: int = 2,
                 seed: int = 0, rel_tol: float = 1e-5):
        self.sc = sc
        self.solver = GroupSolver(sc, kind, seed=seed)
        self.permission = permission
        self.min_residual = min_residual_group
        self.rel_tol = rel_tol
        self.rng = np.random.default_rng(seed)
        self._cache: dict[tuple[int, frozenset], float] = {}
        # effective availability: on churn scenarios inactive devices can
        # associate with no one, so they never become transfer/exchange
        # candidates (and _groups_of keeps them out of every group)
        self.avail = np.asarray(sc.eff_avail)                 # (K, N)
        self._active = sc.active_mask
        # per-edge admission caps: a server at cap rejects inbound transfers
        # (exchanges are 1-for-1, hence cap-neutral and never gated)
        self.cap = sc.capacity
        self.cloud_const = np.asarray(
            sc.lp.lambda_e * cloud_energy(sc.srv)
            + sc.lp.lambda_t * cloud_delay(sc.srv), dtype=np.float64)

    # -- group cost with memoization (the paper's history sets h_i) ---------

    def group_cost(self, server: int, members: frozenset) -> float:
        key = (server, members)
        if key not in self._cache:
            mask = np.zeros(self.sc.n_devices, bool)
            mask[list(members)] = True
            sol = self.solver.solve_batch(np.array([server]), mask[None, :])
            base = float(np.asarray(sol.cost)[0])
            self._cache[key] = base + (self.cloud_const[server] if members else 0.0)
        return self._cache[key]

    def group_costs_batch(self, pairs: list[tuple[int, frozenset]]) -> np.ndarray:
        """Memoized batched evaluation of many (server, members) groups."""
        missing = [p for p in set(pairs) if p not in self._cache]
        if missing:
            servers = np.array([s for s, _ in missing])
            masks = np.zeros((len(missing), self.sc.n_devices), bool)
            for r, (_, mem) in enumerate(missing):
                masks[r, list(mem)] = True
            sols = self.solver.solve_batch(servers, masks)
            costs = np.asarray(sols.cost, dtype=np.float64)
            for p, c in zip(missing, costs):
                self._cache[p] = float(c) + (self.cloud_const[p[0]] if p[1] else 0.0)
        return np.array([self._cache[p] for p in pairs])

    # -- initial association (§II.C / Algorithm 3 line 2) -------------------

    def initial_assignment(self, init: str = "nearest") -> np.ndarray:
        return initial_assignment(self.sc, self.avail, self.rng, init)

    def _check_caps(self, groups) -> None:
        """Explicit assignments must enter the descent cap-feasible; the
        move rules then keep them so (transfers are gated, exchanges are
        cap-neutral)."""
        if self.cap is None:
            return
        over = [i for i, g in enumerate(groups) if len(g) > self.cap[i]]
        if over:
            raise ValueError(
                f"assignment exceeds max_devices at server(s) {over}")

    # -- permission test -----------------------------------------------------

    def _permitted(self, old_costs: list[float], new_costs: list[float]) -> bool:
        scale = max(sum(old_costs), 1e-9)
        improves = sum(new_costs) < sum(old_costs) - self.rel_tol * scale
        if self.permission == "utilitarian":
            return improves
        no_harm = all(nc <= oc + self.rel_tol * max(oc, 1e-9)
                      for oc, nc in zip(old_costs, new_costs))
        return improves and no_harm

    # -- faithful Algorithm 3 ------------------------------------------------

    def run(self, init: str = "nearest", *, max_rounds: int = 200,
            exchange_samples: int = 1,
            assignment: np.ndarray | None = None) -> AssociationResult:
        assignment = (self.initial_assignment(init) if assignment is None
                      else np.asarray(assignment).copy())
        groups = self._groups_of(assignment)
        self._check_caps(groups)
        n, k = self.sc.n_devices, self.sc.n_servers
        n_adj = 0
        trace = [self._total(groups)]

        for rnd in range(max_rounds):
            changed = False
            # line 8-10: every device tries every permitted transfer
            for dev in range(n):
                src = int(assignment[dev])
                if len(groups[src]) <= self.min_residual:
                    continue
                targets = [j for j in range(k)
                           if j != src and self.avail[j, dev]
                           and (self.cap is None
                                or len(groups[j]) < self.cap[j])]
                if not targets:
                    continue
                src_after = groups[src] - {dev}
                pairs = [(src, groups[src]), (src, src_after)]
                for j in targets:
                    pairs += [(j, groups[j]), (j, groups[j] | {dev})]
                self.group_costs_batch(pairs)     # warm the cache in one shot
                best = None
                for j in targets:
                    old = [self.group_cost(src, groups[src]),
                           self.group_cost(j, groups[j])]
                    new = [self.group_cost(src, src_after),
                           self.group_cost(j, groups[j] | {dev})]
                    if self._permitted(old, new):
                        delta = sum(new) - sum(old)
                        if best is None or delta < best[0]:
                            best = (delta, j)
                if best is not None:
                    j = best[1]
                    groups[src] = src_after
                    groups[j] = groups[j] | {dev}
                    assignment[dev] = j
                    n_adj += 1
                    changed = True
                    trace.append(self._total(groups))
            # line 11: random exchange attempts
            for _ in range(exchange_samples):
                if self._try_exchange(assignment, groups):
                    n_adj += 1
                    changed = True
                    trace.append(self._total(groups))
            if not changed:
                return self._finalize(assignment, groups, n_adj, rnd + 1, trace)
        return self._finalize(assignment, groups, n_adj, max_rounds, trace)

    def _try_exchange(self, assignment, groups) -> bool:
        k = self.sc.n_servers
        occupied = [i for i in range(k) if groups[i]]
        if len(occupied) < 2:
            return False
        i, j = self.rng.choice(occupied, size=2, replace=False)
        dev_n = int(self.rng.choice(sorted(groups[i])))
        dev_m = int(self.rng.choice(sorted(groups[j])))
        if not (self.avail[j, dev_n] and self.avail[i, dev_m]):
            return False
        gi = (groups[i] - {dev_n}) | {dev_m}
        gj = (groups[j] - {dev_m}) | {dev_n}
        old = [self.group_cost(i, groups[i]), self.group_cost(j, groups[j])]
        new = [self.group_cost(i, gi), self.group_cost(j, gj)]
        if self._permitted(old, new):
            groups[i], groups[j] = gi, gj
            assignment[dev_n], assignment[dev_m] = j, i
            return True
        return False

    # -- beyond-paper: batched steepest-descent rounds ------------------------

    def run_batched(self, init: str = "nearest", *, max_moves: int = 10_000,
                    exchange_samples: int = 64,
                    assignment: np.ndarray | None = None) -> AssociationResult:
        """Evaluate ALL candidate transfers per round in one vmapped solve and
        apply the single best permitted move (steepest descent). Convergence
        follows from the same finite-strategy/monotone argument as Thm. 3."""
        assignment = (self.initial_assignment(init) if assignment is None
                      else np.asarray(assignment).copy())
        groups = self._groups_of(assignment)
        self._check_caps(groups)
        n, k = self.sc.n_devices, self.sc.n_servers
        n_adj = 0
        trace = [self._total(groups)]
        moves = 0

        while moves < max_moves:
            # candidate transfers: (dev, src, dst)
            cands = []
            pairs = []
            for dev in range(n):
                src = int(assignment[dev])
                if len(groups[src]) <= self.min_residual:
                    continue
                for dst in range(k):
                    if dst == src or not self.avail[dst, dev]:
                        continue
                    if (self.cap is not None
                            and len(groups[dst]) >= self.cap[dst]):
                        continue
                    cands.append((dev, src, dst))
                    pairs += [(src, groups[src]), (src, groups[src] - {dev}),
                              (dst, groups[dst]), (dst, groups[dst] | {dev})]
            best = None
            if cands:
                costs = self.group_costs_batch(pairs).reshape(-1, 4)
                for (dev, src, dst), row in zip(cands, costs):
                    old = [row[0], row[2]]
                    new = [row[1], row[3]]
                    if self._permitted(old, new):
                        delta = sum(new) - sum(old)
                        if best is None or delta < best[0]:
                            best = (delta, dev, src, dst)
            if best is not None:
                _, dev, src, dst = best
                groups[src] = groups[src] - {dev}
                groups[dst] = groups[dst] | {dev}
                assignment[dev] = dst
                n_adj += 1
                moves += 1
                trace.append(self._total(groups))
                continue
            # no transfer: try a batch of sampled exchanges, apply best
            if not self._batched_exchange(assignment, groups, exchange_samples):
                break
            n_adj += 1
            moves += 1
            trace.append(self._total(groups))
        return self._finalize(assignment, groups, n_adj, moves, trace)

    def _batched_exchange(self, assignment, groups, samples: int) -> bool:
        n, k = self.sc.n_devices, self.sc.n_servers
        cands = []
        pairs = []
        for _ in range(samples):
            dev_n, dev_m = self.rng.choice(n, size=2, replace=False)
            i, j = int(assignment[dev_n]), int(assignment[dev_m])
            if i == j or not (self.avail[j, dev_n] and self.avail[i, dev_m]):
                continue
            gi = (groups[i] - {dev_n}) | {dev_m}
            gj = (groups[j] - {dev_m}) | {dev_n}
            cands.append((dev_n, dev_m, i, j, gi, gj))
            pairs += [(i, groups[i]), (i, gi), (j, groups[j]), (j, gj)]
        if not cands:
            return False
        costs = self.group_costs_batch(pairs).reshape(-1, 4)
        best = None
        for (dev_n, dev_m, i, j, gi, gj), row in zip(cands, costs):
            if self._permitted([row[0], row[2]], [row[1], row[3]]):
                delta = (row[1] + row[3]) - (row[0] + row[2])
                if best is None or delta < best[0]:
                    best = (delta, dev_n, dev_m, i, j, gi, gj)
        if best is None:
            return False
        _, dev_n, dev_m, i, j, gi, gj = best
        groups[i], groups[j] = gi, gj
        assignment[dev_n], assignment[dev_m] = j, i
        return True

    # -- bookkeeping -----------------------------------------------------------

    def _groups_of(self, assignment) -> list[frozenset]:
        # inactive devices hold only a parked bookkeeping slot in
        # ``assignment``; they belong to no group and cost nothing
        return [frozenset(np.flatnonzero((assignment == i) & self._active))
                for i in range(self.sc.n_servers)]

    def _total(self, groups) -> float:
        return float(sum(self.group_cost(i, g) for i, g in enumerate(groups)))

    def _finalize(self, assignment, groups, n_adj, n_rounds, trace) -> AssociationResult:
        k = self.sc.n_servers
        servers = np.arange(k)
        masks = np.zeros((k, self.sc.n_devices), bool)
        for i, g in enumerate(groups):
            masks[i, list(g)] = True
        sols = self.solver.solve_batch(servers, masks)
        f = np.asarray(jnp.sum(jnp.where(masks, sols.f, 0.0), axis=0))
        beta = np.asarray(jnp.sum(jnp.where(masks, sols.beta, 0.0), axis=0))
        server_cost = np.asarray(sols.cost)
        # true (15)-(17) costs span the active population only (inactive
        # devices are in no group above, so their f/beta are zero)
        act = np.flatnonzero(self._active)
        dev = self.sc.dev
        if act.size < self.sc.n_devices:
            dev = jax.tree.map(lambda x: x[act], dev)
        e, t, c = global_cost(dev, self.sc.srv,
                              jnp.asarray(np.asarray(assignment)[act]),
                              jnp.asarray(f[act]),
                              jnp.asarray(np.maximum(beta[act], 1e-9)),
                              self.sc.lp)
        return AssociationResult(
            assignment=assignment.copy(), f=f, beta=beta,
            server_cost=server_cost,
            total_cost=self._total(groups),
            true_energy=float(e), true_delay=float(t), true_cost=float(c),
            n_adjustments=n_adj, n_rounds=n_rounds, cost_trace=trace)


# ---------------------------------------------------------------------------
# §V.A benchmark schemes
# ---------------------------------------------------------------------------

def evaluate_scheme(sc: Scenario, scheme: str, *, seed: int = 0,
                    batched: bool = True, engine: str = "fast",
                    profile: str = "default", tiers=None,
                    compact: bool | str = "auto") -> AssociationResult:
    """Run one of the paper's §V.A comparison schemes end-to-end.

      hfel           — edge association + full joint RA (the paper's algorithm)
      random         — random association, full RA, no association iterations
      greedy         — nearest-server association, full RA, no iterations
      comp_opt       — association + optimal-f / uniform-beta RA
      comm_opt       — association + optimal-beta / random-f RA
      uniform        — association + uniform-beta / random-f (no RA opt.)
      proportional   — association + inverse-distance beta / random-f

    ``engine`` selects the association iterator for the iterative schemes:
      fast     — device-resident fused-sweep engine (repro.core.assoc_fast)
      batched  — host-loop steepest descent (AssociationEngine.run_batched)
      loop     — faithful Algorithm 3 (AssociationEngine.run)
    ``batched=False`` is a legacy alias for ``engine="loop"``.

    Fast-engine options: ``compact`` picks the sweep space — all run the one
    unified move-selection kernel with different slot-index maps: ``False``
    dense (K, N), ``True`` flat compacted reachable-slot (K, R),
    ``"bucketed"`` adaptive per-bucket (K_b, R_b) widths, ``"auto"`` compacts
    when availability is sparse — and ``tiers`` — a ``ra.TIER_PLANS`` plan
    name or profile tuple —
    switches to the multi-tier warm-started descent driver
    (:meth:`~repro.core.assoc_fast.FastAssociationEngine.run_tiered`), in
    which case ``profile`` only sets the engine default and the tier plan
    governs the sweeps.
    """
    kind = {"hfel": "fast", "random": "fast", "greedy": "fast",
            "comp_opt": "comp_only", "comm_opt": "comm_only",
            "uniform": "uniform", "proportional": "proportional"}[scheme]
    if scheme in ("random", "greedy"):
        eng = AssociationEngine(sc, kind=kind, seed=seed)
        init = "random" if scheme == "random" else "nearest"
        assignment = eng.initial_assignment(init)
        groups = eng._groups_of(assignment)
        return eng._finalize(assignment, groups, 0, 0,
                             [eng._total(groups)])
    init = "random"
    if not batched:
        engine = "loop"
    if engine == "fast":
        from repro.core.assoc_fast import FastAssociationEngine
        eng = FastAssociationEngine(sc, kind=kind, seed=seed,
                                    profile=profile, compact=compact)
        if tiers is not None:
            return eng.run_tiered(init, tiers=tiers)
        return eng.run(init)
    if tiers is not None:
        raise ValueError("tiered descent requires engine='fast'")
    eng = AssociationEngine(sc, kind=kind, seed=seed)
    if engine == "batched":
        return eng.run_batched(init)
    if engine == "loop":
        return eng.run(init)
    raise ValueError(engine)
