"""Update compression for the expensive (cloud / pod-axis) tier.

The paper attacks WAN communication cost architecturally (edge aggregation);
these operators attack it numerically — the standard distributed-optimization
companions for hierarchical FL at datacenter scale:

* :class:`TopKCompressor` — magnitude top-k sparsification with error
  feedback (the residual is carried into the next round, preserving
  convergence).
* :class:`Int8Compressor` — symmetric per-tensor int8 quantization of
  updates (4x over f32, 2x over bf16 on the wire).

Both operate leaf-wise on pytrees and report their wire bytes so the
benchmarks can account collective-term savings.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.utils import tree_zeros_like


@dataclass(frozen=True)
class TopKCompressor:
    """Keep the top ``ratio`` fraction of entries (by magnitude) per leaf."""

    ratio: float = 0.01

    def init_state(self, params):
        return tree_zeros_like(params)          # error-feedback residual

    def compress(self, update, state):
        """Returns (sparse_update, new_state). sparse_update is dense-shaped
        with zeros off-support (the wire format would ship indices+values;
        wire_bytes() accounts for that)."""

        def one(u, e):
            x = u + e
            flat = x.reshape(-1)
            k = max(int(flat.size * self.ratio), 1)
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            kept = jnp.where(jnp.abs(x) >= thresh, x, 0.0)
            return kept, x - kept

        pairs = jax.tree.map(one, update, state)
        kept = jax.tree.map(lambda p: p[0], pairs,
                            is_leaf=lambda p: isinstance(p, tuple))
        resid = jax.tree.map(lambda p: p[1], pairs,
                             is_leaf=lambda p: isinstance(p, tuple))
        return kept, resid

    def wire_bytes(self, params) -> int:
        """4B value + 4B index per kept entry."""
        total = 0
        for leaf in jax.tree.leaves(params):
            k = max(int(leaf.size * self.ratio), 1)
            total += 8 * k
        return total


@dataclass(frozen=True)
class Int8Compressor:
    """Symmetric per-tensor int8 quantization with straight-through dequant."""

    def init_state(self, params):
        return ()

    def compress(self, update, state):
        def one(u):
            scale = jnp.maximum(jnp.max(jnp.abs(u)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(u / scale), -127, 127).astype(jnp.int8)
            return q.astype(u.dtype) * scale

        return jax.tree.map(one, update), state

    def wire_bytes(self, params) -> int:
        return sum(leaf.size + 4 for leaf in jax.tree.leaves(params))


def no_compression_bytes(params, dtype_bytes: int = 4) -> int:
    return sum(leaf.size * dtype_bytes for leaf in jax.tree.leaves(params))
