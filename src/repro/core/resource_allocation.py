"""Optimal computation/communication resource allocation — paper Section III.

Solves problem (18) for one edge server's training group S_i:

    min  C_i(f, beta) = sum_n [ a_n/beta_n + b_n f_n^2 ]
                        + w * max_n [ d_n/beta_n + e_n/f_n ]
    s.t. sum_n beta_n <= 1,  0 < beta_n <= 1,  f_min <= f_n <= f_max

with the Section-III constants (a, b, d, e, w) from
:func:`repro.core.cost_model.ra_constants`.

Four solvers are provided; all are jit-able and vmap-able over padded groups
(``mask`` selects the active members):

* :func:`solve_paper`        — Algorithm 2 *faithful*: substitute the KKT
  bandwidth rule beta(f) of Theorem 2 / eq. (19), then solve the reduced
  f-only convex problem (32) by a projected first-order method with an
  annealed log-sum-exp smoothing of the max (standing in for the paper's
  "CVX / IPOPT").
* :func:`solve_fixed_point`  — fast beyond-paper solver exploiting the full
  KKT structure: at the optimum every device with interior f finishes at a
  common deadline t (eq. 25 with tau_n = 2 b_n f_n^3 / e_n > 0) and
  sum_n tau_n = W (eq. 23); bisection on t with an inner beta<->f fixed
  point. Near-exact in the common interior regime; used to screen the many
  candidate groups of edge association.
* :func:`solve_exact`        — exact nested parametric solver: golden-section
  over the deadline t, bisection over the bandwidth multiplier nu, per-device
  golden-section for the (convex) boundary trade-off. Handles all box/cap
  clipping cases; the reported final costs use this.
* :func:`solve_reference`    — plain projected subgradient on (f, beta)
  jointly. Slow, structure-free; the test oracle.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import dataclasses
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.cost_model import RAConstants, ra_objective

_GOLDEN = 0.6180339887498949
_EPS = 1e-12


def _register(cls):
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])
    return cls


@_register
@dataclass
class RASolution:
    f: jnp.ndarray          # (N,) optimal CPU frequencies (padded: f_min)
    beta: jnp.ndarray       # (N,) optimal bandwidth shares (padded: 0)
    cost: jnp.ndarray       # scalar, optimal value of (18); 0 for empty group
    deadline: jnp.ndarray   # scalar t* = max_n d/beta + e/f


def _golden_min(fn, lo, hi, n_iter: int):
    """Golden-section minimize with the classic single-eval recurrence.

    Each iteration shrinks the bracket by the golden ratio while evaluating
    ``fn`` ONCE (the surviving interior probe is reused via G^2 = 1 - G),
    instead of the two evaluations per iteration of the naive form — the
    dominant sequential-depth cost of every solver here. ``lo``/``hi`` may be
    arrays (vectorized independent searches); returns the bracket midpoint.
    """
    m1 = hi - _GOLDEN * (hi - lo)
    m2 = lo + _GOLDEN * (hi - lo)
    c1, c2 = fn(m1), fn(m2)

    def body(_, st):
        lo, hi, m1, m2, c1, c2 = st
        go_right = c1 > c2
        lo = jnp.where(go_right, m1, lo)
        hi = jnp.where(go_right, hi, m2)
        m1n = hi - _GOLDEN * (hi - lo)
        m2n = lo + _GOLDEN * (hi - lo)
        # the surviving probe becomes the opposite interior point; only the
        # freshly exposed point needs an evaluation
        point = jnp.where(go_right, m2n, m1n)
        cp = fn(point)
        m1_new = jnp.where(go_right, m2, point)
        c1_new = jnp.where(go_right, c2, cp)
        m2_new = jnp.where(go_right, point, m1)
        c2_new = jnp.where(go_right, cp, c1)
        return lo, hi, m1_new, m2_new, c1_new, c2_new

    lo, hi, *_ = lax.fori_loop(0, n_iter, body, (lo, hi, m1, m2, c1, c2))
    return 0.5 * (lo + hi)


def _masked_beta_norm(s: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Normalize positive scores s to sum to 1 over the active set."""
    s = jnp.where(mask, s, 0.0)
    tot = jnp.maximum(jnp.sum(s), _EPS)
    return jnp.where(mask, s / tot, 0.0)


def _finalize(c: RAConstants, mask, f, beta) -> RASolution:
    any_active = jnp.any(mask)
    f = jnp.where(mask, jnp.clip(f, c.f_min, c.f_max), c.f_min)
    beta = _masked_beta_norm(jnp.maximum(beta, _EPS), mask)
    safe_beta = jnp.where(mask, jnp.maximum(beta, _EPS), 1.0)
    cost = jnp.where(any_active, ra_objective(c, mask, f, safe_beta), 0.0)
    deadline = jnp.max(jnp.where(mask, c.d / safe_beta + c.e / f, 0.0))
    return RASolution(f=f, beta=beta, cost=cost, deadline=deadline)


# ---------------------------------------------------------------------------
# Theorem 2: the closed-form bandwidth rule, eq. (19)
# ---------------------------------------------------------------------------

def beta_of_f(c: RAConstants, mask: jnp.ndarray, f: jnp.ndarray) -> jnp.ndarray:
    """beta*_n  proportional to  (a_n + (2 b_n f_n^3 / e_n) d_n)^(1/3)."""
    tau = 2.0 * c.b * f**3 / jnp.maximum(c.e, _EPS)
    score = jnp.cbrt(jnp.maximum(c.a + tau * c.d, _EPS))
    return _masked_beta_norm(score, mask)


# ---------------------------------------------------------------------------
# Solver 1 — Algorithm 2 (paper-faithful)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_steps",))
def solve_paper(c: RAConstants, mask: jnp.ndarray, *, n_steps: int = 400) -> RASolution:
    """Algorithm 2: replace beta by eq. (19), solve (32) over f only.

    The max term of (32) is smoothed with an annealed log-sum-exp
    (temperature decays geometrically), and the box constraint on f is kept
    by projection. Adam is used as the first-order engine — the role the
    paper assigns to an off-the-shelf convex solver.
    """
    n = c.a.shape[0]

    def objective(f, temp):
        beta = beta_of_f(c, mask, f)
        safe_beta = jnp.where(mask, jnp.maximum(beta, _EPS), 1.0)
        s = jnp.sum(jnp.where(mask, c.a / safe_beta + c.b * f**2, 0.0))
        per_max = jnp.where(mask, c.d / safe_beta + c.e / f, -jnp.inf)
        # temperature-scaled LSE -> max as temp -> 0
        m = temp * jax.nn.logsumexp(per_max / temp)
        return s + c.w * m

    grad_fn = jax.grad(objective)
    f0 = jnp.sqrt(c.f_min * c.f_max)
    scale = c.f_max - c.f_min
    t_hot = jnp.asarray(1e2, jnp.float32)
    decay = (1e-4 / 1e2) ** (1.0 / max(n_steps - 1, 1))

    def step(carry, _):
        f, m1, m2, k, temp = carry
        g = grad_fn(f, temp) * scale          # precondition by box width
        m1 = 0.9 * m1 + 0.1 * g
        m2 = 0.999 * m2 + 0.001 * g * g
        m1h = m1 / (1 - 0.9 ** (k + 1))
        m2h = m2 / (1 - 0.999 ** (k + 1))
        f = f - 0.02 * scale * m1h / (jnp.sqrt(m2h) + 1e-8)
        f = jnp.clip(f, c.f_min, c.f_max)
        return (f, m1, m2, k + 1, temp * decay), None

    init = (f0, jnp.zeros(n), jnp.zeros(n), jnp.asarray(0), t_hot)
    (f, _, _, _, _), _ = lax.scan(step, init, None, length=n_steps)
    return _finalize(c, mask, f, beta_of_f(c, mask, f))


# ---------------------------------------------------------------------------
# Solver 2 — KKT fixed point (fast screening solver)
# ---------------------------------------------------------------------------

def _deadline_bracket(c: RAConstants, mask, n_bracket: int = 60):
    """Feasible deadline range.

    Lower: smallest t with sum_n d_n/(t - e_n/f_max) <= 1 (every device at
    max frequency, bandwidth exactly exhausted). Upper: same with f_min.
    Both bisections run simultaneously on a stacked (2, N) array so the
    sequential depth is ``n_bracket`` steps, not 2x that.
    """
    f2 = jnp.stack([c.f_max, c.f_min])                         # (2, N)

    def sum_beta_min(t):
        slack = t[:, None] - c.e / f2
        b = jnp.where(mask, c.d / jnp.maximum(slack, _EPS), 0.0)
        b = jnp.where(mask & (slack <= 0), 1e6, b)
        return jnp.sum(b, axis=-1)

    lo = jnp.max(jnp.where(mask, c.e / f2 + c.d, 0.0), axis=-1)  # device floor
    hi = lo + jnp.sum(jnp.where(mask, c.d, 0.0)) * 1e4 + 1.0

    def body(_, lohi):
        lo_, hi_ = lohi
        mid = 0.5 * (lo_ + hi_)
        ok = sum_beta_min(mid) <= 1.0
        return (jnp.where(ok, lo_, mid), jnp.where(ok, mid, hi_))

    lo_, hi_ = lax.fori_loop(0, n_bracket, body, (lo, hi))
    return hi_[0], hi_[1]


# Iteration presets for :func:`solve_fixed_point`. "default" is the reference
# accuracy used by the association parity gates; "screen" / "coarse" trade a
# little deadline resolution for 2-4x fewer inner iterations when the solver
# runs inside the fused candidate sweeps of ``repro.core.assoc_fast`` at large
# device counts (every candidate group pays n_golden * n_inner + 2 * n_bracket
# vector ops, so these knobs dominate sweep cost).
SCREEN_PROFILES: dict[str, dict[str, int]] = {
    "default": dict(n_golden=48, n_inner=12, n_bracket=60),
    "screen": dict(n_golden=32, n_inner=8, n_bracket=40),
    "coarse": dict(n_golden=16, n_inner=6, n_bracket=24),
}

# Named multi-tier descent plans for the association engines: each entry is a
# sequence of SCREEN_PROFILES names run back-to-back, every tier warm-started
# from the previous tier's stable assignment. The cheap leading tiers apply
# the bulk of the adjustments; the trailing "default" tier polishes the
# stable point back to reference accuracy at a few moves' cost.
TIER_PLANS: dict[str, tuple[str, ...]] = {
    "default_only": ("default",),
    "two_tier": ("coarse", "default"),
    "three_tier": ("coarse", "screen", "default"),
}


def resolve_tiers(tiers) -> tuple[str, ...]:
    """Normalize a tier spec into a tuple of screening-profile names.

    Accepts a :data:`TIER_PLANS` plan name, a single profile name, or an
    iterable of profile names; every resolved profile must exist in
    :data:`SCREEN_PROFILES`.
    """
    if isinstance(tiers, str):
        tiers = TIER_PLANS.get(tiers, (tiers,))
    tiers = tuple(tiers)
    if not tiers:
        raise ValueError("tier plan resolves to no profiles")
    unknown = [t for t in tiers if t not in SCREEN_PROFILES]
    if unknown:
        raise ValueError(
            f"unknown screening profile(s) {unknown}; expected names from "
            f"SCREEN_PROFILES {sorted(SCREEN_PROFILES)} or a TIER_PLANS "
            f"plan {sorted(TIER_PLANS)}")
    return tiers


@partial(jax.jit, static_argnames=("n_golden", "n_inner", "n_bracket"))
def solve_fixed_point(c: RAConstants, mask: jnp.ndarray, *, n_golden: int = 48,
                      n_inner: int = 12, n_bracket: int = 60) -> RASolution:
    """Golden-section on the common deadline t along the KKT path.

    At a fixed t, beta follows eq. (19) and f the tightness relation
    f_n = clip(e_n / (t - d_n/beta_n), box) — iterated as a fixed point.
    Rather than root-finding the eq.-(23) residual sum tau_n = W (which has
    no root once box constraints clip f, and then misplaces t badly), the
    *exact objective* (18) is evaluated along this one-parameter family and
    minimized by golden-section: exact whenever the interior KKT structure
    holds, and never pathological when it does not.

    ``(n_golden, n_inner, n_bracket)`` presets live in :data:`SCREEN_PROFILES`.
    """
    t_lo, t_hi = _deadline_bracket(c, mask, n_bracket)
    t_lo = t_lo * (1.0 + 1e-6)
    t_hi = jnp.maximum(t_hi * 1.5, t_lo * 4.0) + 1.0

    def fb_of_t(t):
        def body(_, f):
            beta = beta_of_f(c, mask, f)
            safe_beta = jnp.where(mask, jnp.maximum(beta, _EPS), 1.0)
            slack = t - c.d / safe_beta
            f_new = jnp.where(slack > 0, c.e / jnp.maximum(slack, _EPS), c.f_max)
            return jnp.clip(f_new, c.f_min, c.f_max)

        f = lax.fori_loop(0, n_inner, body, jnp.sqrt(c.f_min * c.f_max))
        return f, beta_of_f(c, mask, f)

    def cost_of_t(t):
        f, beta = fb_of_t(t)
        safe_beta = jnp.where(mask, jnp.maximum(beta, _EPS), 1.0)
        return ra_objective(c, mask, f, safe_beta)

    f, beta = fb_of_t(_golden_min(cost_of_t, t_lo, t_hi, n_golden))
    return _finalize(c, mask, f, beta)


def solve_fixed_point_batched(c: RAConstants, masks: jnp.ndarray, *,
                              n_golden: int = 48, n_inner: int = 12,
                              n_bracket: int = 60,
                              backend: str = "xla") -> RASolution:
    """Solve a BATCH of independent groups along the KKT deadline path.

    ``c`` holds the constants batched over groups — leaves ``(G, R)``, ``w``
    ``(G,)`` — and ``masks`` is ``(G, R)``. ``backend`` selects the engine:

    * ``"xla"`` — :func:`solve_fixed_point` vmapped over the batch; the
      traced per-group graph is identical to the scalar solver's, so results
      are bit-identical to solving each group alone.
    * ``"pallas"`` — the fused :mod:`repro.kernels.golden_section` kernel
      (interpret mode off-TPU): the whole bracket + golden-section + inner
      fixed-point stack runs as one VMEM-resident kernel per group block.
      Matches the XLA path to float32 rounding, not bit-exactly — parity is
      pinned at rtol 2e-4 on cost (tests/test_assoc_sharded.py).
    """
    if backend == "xla":
        return jax.vmap(
            lambda cc, m: solve_fixed_point(cc, m, n_golden=n_golden,
                                            n_inner=n_inner,
                                            n_bracket=n_bracket))(c, masks)
    if backend == "pallas":
        from repro.kernels import ops as _kops
        f, beta, cost, deadline = _kops.golden_section_solve(
            c.a, c.b, c.d, c.e, c.w, c.f_min, c.f_max, masks,
            n_golden=n_golden, n_inner=n_inner, n_bracket=n_bracket)
        return RASolution(f=f, beta=beta, cost=cost, deadline=deadline)
    raise ValueError(f"unknown RA backend {backend!r}; "
                     "expected 'xla' or 'pallas'")


# ---------------------------------------------------------------------------
# Solver 3 — exact nested parametric solver (beyond-paper)
# ---------------------------------------------------------------------------

def _inner_beta_f(c: RAConstants, mask, t, nu, n_beta: int = 32):
    """For fixed (deadline t, bandwidth price nu): per-device minimize

        psi(beta) = a/beta + b * f(beta)^2 + nu*beta,
        f(beta)   = clip(e / (t - d/beta), f_min, f_max)

    over beta in [beta_feas(t), 1]. psi is convex (see DESIGN.md §2);
    vectorized golden-section across devices.
    """
    # feasible lower end: meet deadline at f_max
    slack_max = t - c.e / c.f_max
    b_lo = jnp.where(slack_max > 0, c.d / jnp.maximum(slack_max, _EPS), 1.0)
    b_lo = jnp.clip(b_lo, _EPS, 1.0)
    b_hi = jnp.ones_like(b_lo)

    def f_of_beta(beta):
        slack = t - c.d / jnp.maximum(beta, _EPS)
        f = jnp.where(slack > 0, c.e / jnp.maximum(slack, _EPS), c.f_max)
        return jnp.clip(f, c.f_min, c.f_max)

    def psi(beta):
        f = f_of_beta(beta)
        return c.a / jnp.maximum(beta, _EPS) + c.b * f**2 + nu * beta

    beta = _golden_min(psi, b_lo, b_hi, n_beta)    # vectorized across devices
    return beta, f_of_beta(beta)


def _solve_fixed_t(c: RAConstants, mask, t, n_nu: int = 40):
    """Exact inner solve at fixed deadline t: bisect the bandwidth price nu
    so that the active betas sum to 1 (sum beta decreasing in nu)."""
    def sum_beta(nu):
        beta, _ = _inner_beta_f(c, mask, t, nu)
        return jnp.sum(jnp.where(mask, beta, 0.0))

    # bracket: nu=0 gives each beta -> its unconstrained max (sum >= 1 when
    # the simplex binds); grow hi until sum <= 1.
    def grow(_, hi):
        return jnp.where(sum_beta(hi) > 1.0, hi * 8.0, hi)

    hi = lax.fori_loop(0, 12, grow, jnp.asarray(1.0, jnp.float32))
    simplex_binds = sum_beta(jnp.asarray(0.0, jnp.float32)) > 1.0

    def body(_, lohi):
        lo_, hi_ = lohi
        mid = 0.5 * (lo_ + hi_)
        over = sum_beta(mid) > 1.0
        return (jnp.where(over, mid, lo_), jnp.where(over, hi_, mid))

    lo_, hi_ = lax.fori_loop(0, n_nu, body, (jnp.asarray(0.0, jnp.float32), hi))
    nu = jnp.where(simplex_binds, 0.5 * (lo_ + hi_), 0.0)
    beta, f = _inner_beta_f(c, mask, t, nu)
    value = jnp.sum(jnp.where(mask, c.a / jnp.maximum(beta, _EPS) + c.b * f**2, 0.0))
    return beta, f, value


@partial(jax.jit, static_argnames=("n_outer",))
def solve_exact(c: RAConstants, mask: jnp.ndarray, *, n_outer: int = 44) -> RASolution:
    """Golden-section over t of J(t) = inner_value(t) + w*t (convex)."""
    t_lo, t_hi = _deadline_bracket(c, mask)
    t_lo = t_lo * (1.0 + 1e-6)
    t_hi = jnp.maximum(t_hi * 2.0, t_lo * 4.0)

    def j_of_t(t):
        _, _, value = _solve_fixed_t(c, mask, t)
        return value + c.w * t

    t_star = _golden_min(j_of_t, t_lo, t_hi, n_outer)
    beta, f, _ = _solve_fixed_t(c, mask, t_star)
    return _finalize(c, mask, f, beta)


# ---------------------------------------------------------------------------
# Solver 4 — projected subgradient reference (test oracle)
# ---------------------------------------------------------------------------

def _project_simplex_cap(beta: jnp.ndarray, mask: jnp.ndarray,
                         lo: float = 1e-6) -> jnp.ndarray:
    """Euclidean projection onto {lo <= beta_n <= 1, sum_active beta <= 1}."""
    n_active = jnp.maximum(jnp.sum(mask), 1)
    beta = jnp.clip(jnp.where(mask, beta, 0.0), lo, 1.0)
    need = jnp.sum(beta) > 1.0

    # bisection on the shift s: sum clip(beta - s, lo, 1) = 1
    def body(_, lohi):
        l, h = lohi
        mid = 0.5 * (l + h)
        tot = jnp.sum(jnp.where(mask, jnp.clip(beta - mid, lo, 1.0), 0.0))
        return (jnp.where(tot > 1.0, mid, l), jnp.where(tot > 1.0, h, mid))

    l, h = lax.fori_loop(0, 50, body, (jnp.asarray(0.0), jnp.max(beta)))
    shifted = jnp.clip(beta - 0.5 * (l + h), lo, 1.0)
    out = jnp.where(need, shifted, beta)
    return jnp.where(mask, out, 0.0)


@partial(jax.jit, static_argnames=("n_steps",))
def solve_reference(c: RAConstants, mask: jnp.ndarray, *, n_steps: int = 4000,
                    seed: int = 0) -> RASolution:
    """Projected subgradient on (f, beta) jointly; keeps the best iterate."""
    def objective(fb):
        f, beta = fb
        safe_beta = jnp.where(mask, jnp.maximum(beta, _EPS), 1.0)
        return ra_objective(c, mask, f, safe_beta)

    grad_fn = jax.grad(objective)
    f0 = jnp.sqrt(c.f_min * c.f_max)
    b0 = _project_simplex_cap(jnp.where(mask, 1.0, 0.0) /
                              jnp.maximum(jnp.sum(mask), 1), mask)

    def step(carry, k):
        f, beta, best_f, best_b, best_v = carry
        gf, gb = grad_fn((f, beta))
        lr = 1.0 / jnp.sqrt(k + 1.0)
        f = jnp.clip(f - lr * (c.f_max - c.f_min) * 0.1 *
                     gf / (jnp.abs(gf) + 1e-20), c.f_min, c.f_max)
        beta = _project_simplex_cap(
            beta - lr * 0.05 * gb / (jnp.linalg.norm(gb) + 1e-20), mask)
        v = objective((f, beta))
        better = v < best_v
        best = (jnp.where(better, f, best_f), jnp.where(better, beta, best_b),
                jnp.where(better, v, best_v))
        return (f, beta, *best), None

    init = (f0, b0, f0, b0, objective((f0, b0)))
    (_, _, best_f, best_b, _), _ = lax.scan(step, init, jnp.arange(n_steps))
    return _finalize(c, mask, best_f, best_b)


# ---------------------------------------------------------------------------
# Partial-optimization variants for the paper's §V.A benchmark schemes
# ---------------------------------------------------------------------------

@jax.jit
def optimize_f_given_beta(c: RAConstants, mask: jnp.ndarray,
                          beta: jnp.ndarray) -> RASolution:
    """"Computation optimization" scheme: optimal f under a fixed beta.

    Exact via golden-section on the deadline: at fixed t the objective is
    increasing in f so f_n(t) = clip(e_n/(t - d_n/beta_n), box); the value
    U(t) = sum b f(t)^2 + w t is convex in t.
    """
    safe_beta = jnp.where(mask, jnp.maximum(beta, _EPS), 1.0)
    floor = c.d / safe_beta
    t_lo = jnp.max(jnp.where(mask, floor + c.e / c.f_max, 0.0)) * (1 + 1e-6)
    t_hi = jnp.max(jnp.where(mask, floor + c.e / c.f_min, 0.0)) * 1.5 + 1.0

    def f_of_t(t):
        slack = t - floor
        f = jnp.where(slack > 0, c.e / jnp.maximum(slack, _EPS), c.f_max)
        return jnp.clip(f, c.f_min, c.f_max)

    def u_of_t(t):
        f = f_of_t(t)
        return jnp.sum(jnp.where(mask, c.b * f**2, 0.0)) + c.w * t

    f = f_of_t(_golden_min(u_of_t, t_lo, t_hi, 48))
    any_active = jnp.any(mask)
    cost = jnp.where(any_active, ra_objective(c, mask, f, safe_beta), 0.0)
    deadline = jnp.max(jnp.where(mask, c.d / safe_beta + c.e / f, 0.0))
    return RASolution(f=jnp.where(mask, f, c.f_min),
                      beta=jnp.where(mask, beta, 0.0), cost=cost,
                      deadline=deadline)


@jax.jit
def optimize_beta_given_f(c: RAConstants, mask: jnp.ndarray,
                          f: jnp.ndarray) -> RASolution:
    """"Communication optimization" scheme: optimal beta under a fixed f.

    Exact: golden-section over t with an inner water-filling
    beta_n(t, nu) = max(d_n/(t - e_n/f_n), sqrt(a_n/nu)) and bisection on nu
    for sum beta = 1.
    """
    e_over_f = c.e / jnp.clip(f, c.f_min, c.f_max)

    def betas(t, nu):
        b_floor = jnp.where(t > e_over_f,
                            c.d / jnp.maximum(t - e_over_f, _EPS), 1.0)
        b_free = jnp.sqrt(c.a / jnp.maximum(nu, _EPS))
        return jnp.clip(jnp.maximum(b_floor, b_free), _EPS, 1.0)

    def solve_nu(t):
        def sum_b(nu):
            return jnp.sum(jnp.where(mask, betas(t, nu), 0.0))

        hi0 = jnp.asarray(1.0, jnp.float32)
        hi = lax.fori_loop(0, 14, lambda _, h: jnp.where(sum_b(h) > 1, h * 8, h), hi0)

        def body(_, lohi):
            l, h = lohi
            mid = 0.5 * (l + h)
            return (jnp.where(sum_b(mid) > 1, mid, l),
                    jnp.where(sum_b(mid) > 1, h, mid))

        l, h = lax.fori_loop(0, 44, body, (jnp.asarray(0.0, jnp.float32), hi))
        return 0.5 * (l + h)

    # feasible t: sum of beta floors <= 1
    def sum_floor(t):
        b = jnp.where(t > e_over_f, c.d / jnp.maximum(t - e_over_f, _EPS), 1e6)
        return jnp.sum(jnp.where(mask, b, 0.0))

    lo0 = jnp.max(jnp.where(mask, e_over_f + c.d, 0.0))
    hi0 = lo0 + jnp.sum(jnp.where(mask, c.d, 0.0)) * 1e4 + 1.0

    def fbody(_, lohi):
        l, h = lohi
        mid = 0.5 * (l + h)
        ok = sum_floor(mid) <= 1.0
        return (jnp.where(ok, l, mid), jnp.where(ok, mid, h))

    _, t_lo = lax.fori_loop(0, 60, fbody, (lo0, hi0))
    t_hi = t_lo * 4.0 + 1.0

    def v_of_t(t):
        beta = betas(t, solve_nu(t))
        return jnp.sum(jnp.where(mask, c.a / beta, 0.0)) + c.w * t

    t_star = _golden_min(v_of_t, t_lo * (1 + 1e-6), t_hi, 44)
    beta = _masked_beta_norm(betas(t_star, solve_nu(t_star)), mask)
    return _finalize(c, mask, jnp.clip(f, c.f_min, c.f_max), beta)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

SOLVERS = {
    "paper": solve_paper,
    "fixed_point": solve_fixed_point,
    "exact": solve_exact,
    "reference": solve_reference,
}


def solve(c: RAConstants, mask: jnp.ndarray, method: str = "exact") -> RASolution:
    """Solve problem (18). ``method`` in {paper, fixed_point, exact, reference}."""
    return SOLVERS[method](c, mask)
