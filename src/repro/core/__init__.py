"""Core HFEL contribution: cost model, resource allocation, edge association,
hierarchical aggregation, and update compression."""

from repro.core.cost_model import (DeviceParams, LearningParams, RAConstants,
                                   ServerParams, global_cost, ra_constants,
                                   ra_objective)
from repro.core.scenario import (DeviceClientBridge, Scenario, ScenarioDelta,
                                 device_client_bridge, diff_scenarios,
                                 make_large_scenario, make_scenario,
                                 perturb_scenario)
from repro.core.resource_allocation import (RASolution, beta_of_f, solve,
                                            solve_exact, solve_fixed_point,
                                            solve_paper, solve_reference)
from repro.core.edge_association import (AssociationEngine, AssociationResult,
                                         GroupSolver, NoFeasibleServerError,
                                         evaluate_scheme, greedy_admission,
                                         nearest_feasible, parked_slots,
                                         solve_group)
from repro.core.assoc_fast import (FastAssociationEngine,
                                   assignment_true_cost, repair_assignment)
from repro.core.hierarchy import (SyncLevel, SyncSchedule, cloud_aggregate,
                                  edge_aggregate, hierarchical_sync, psum_mean)
from repro.core.compression import Int8Compressor, TopKCompressor

__all__ = [
    "DeviceParams", "LearningParams", "RAConstants", "ServerParams",
    "global_cost", "ra_constants", "ra_objective",
    "DeviceClientBridge", "Scenario", "ScenarioDelta",
    "device_client_bridge", "diff_scenarios", "make_large_scenario",
    "make_scenario", "perturb_scenario",
    "RASolution", "beta_of_f", "solve", "solve_exact", "solve_fixed_point",
    "solve_paper", "solve_reference",
    "AssociationEngine", "AssociationResult", "FastAssociationEngine",
    "GroupSolver", "NoFeasibleServerError", "assignment_true_cost",
    "evaluate_scheme", "greedy_admission", "nearest_feasible",
    "parked_slots", "repair_assignment", "solve_group",
    "SyncLevel", "SyncSchedule", "cloud_aggregate", "edge_aggregate",
    "hierarchical_sync", "psum_mean",
    "Int8Compressor", "TopKCompressor",
]
