"""HFEL cost model — paper eqs. (1)-(17).

All quantities are vectorized over devices (and, where noted, over edge
servers) so the whole model is jit/vmap friendly. Units:

  * time    — seconds
  * energy  — joules
  * rates   — nats/second (the paper's eq. (5) uses ``ln``, i.e. nats)
  * model / update sizes — nats
  * CPU frequency — cycles/second (Hz)

Naming vs. the paper (Table I): the paper overloads ``B``/``D``/``E`` for
both physical quantities and the derived constants of Section III.  Here the
physical quantities keep descriptive names and the Section-III constants are
grouped in :class:`RAConstants` with lowercase fields (a, b, d, e, w).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


def _register(cls):
    """Register a dataclass of arrays as a JAX pytree."""
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_dataclass(cls, data_fields=fields, meta_fields=[])
    return cls


@dataclass(frozen=True)
class LearningParams:
    """Learning-task constants (paper §II.A).

    L(theta) = mu * log(1/theta)              — eq. under (1), local iterations
    I(eps, theta) = delta*log(1/eps)/(1-theta) — eq. (9), edge iterations
    """

    theta: float = 0.5          # local accuracy
    epsilon: float = 0.1        # edge accuracy
    mu: float = 14.4            # local-iteration constant (=> L ≈ 10)
    delta: float = 2.17         # edge-iteration constant  (=> I ≈ 10)
    lambda_e: float = 0.5       # energy weight  (eq. 17)
    lambda_t: float = 0.5       # delay weight   (eq. 17)

    @property
    def local_iters(self) -> float:
        return self.mu * math.log(1.0 / self.theta)

    @property
    def edge_iters(self) -> float:
        return self.delta * math.log(1.0 / self.epsilon) / (1.0 - self.theta)


@_register
@dataclass
class DeviceParams:
    """Per-device physical parameters; every field is an array of shape (N,)."""

    cycles_per_iter: jnp.ndarray   # c_n * |D_n|   (cycles for ONE local iteration)
    data_samples: jnp.ndarray      # |D_n|         (aggregation weights, eq. 8)
    model_nats: jnp.ndarray        # d_n           (update size in nats)
    tx_power: jnp.ndarray          # p_n           (W)
    channel_gain: jnp.ndarray      # h_n           (dimensionless)
    alpha: jnp.ndarray             # alpha_n       (capacitance coefficient, F)
    f_min: jnp.ndarray             # Hz
    f_max: jnp.ndarray             # Hz

    @property
    def n_devices(self) -> int:
        return int(self.cycles_per_iter.shape[0])


@_register
@dataclass
class ServerParams:
    """Per-edge-server parameters; every field is an array of shape (K,)."""

    bandwidth: jnp.ndarray         # B_i  (Hz)
    noise: jnp.ndarray             # N_0  (W)
    cloud_rate: jnp.ndarray        # r_i  (nats/s, edge -> cloud)
    cloud_power: jnp.ndarray       # p_i  (W)
    cloud_nats: jnp.ndarray        # d_i  (edge update size in nats)

    @property
    def n_servers(self) -> int:
        return int(self.bandwidth.shape[0])


# ---------------------------------------------------------------------------
# Primitive overheads, eqs. (3)-(7), (12)-(13)
# ---------------------------------------------------------------------------

def spectral_efficiency(dev: DeviceParams, noise: jnp.ndarray) -> jnp.ndarray:
    """ln(1 + h_n p_n / N_0) — nats/s per Hz of allocated bandwidth (eq. 5)."""
    return jnp.log1p(dev.channel_gain * dev.tx_power / noise)


def tx_rate(beta: jnp.ndarray, bandwidth: jnp.ndarray, dev: DeviceParams,
            noise: jnp.ndarray) -> jnp.ndarray:
    """r_n = beta * B_i * ln(1 + h p / N0)  (eq. 5)."""
    return beta * bandwidth * spectral_efficiency(dev, noise)


def comp_time(dev: DeviceParams, f: jnp.ndarray, lp: LearningParams) -> jnp.ndarray:
    """t^cmp_n — eq. (3), delay of L(theta) local iterations."""
    return lp.local_iters * dev.cycles_per_iter / f


def comp_energy(dev: DeviceParams, f: jnp.ndarray, lp: LearningParams) -> jnp.ndarray:
    """e^cmp_n — eq. (4)."""
    return lp.local_iters * 0.5 * dev.alpha * jnp.square(f) * dev.cycles_per_iter


def comm_time(dev: DeviceParams, beta: jnp.ndarray, bandwidth: jnp.ndarray,
              noise: jnp.ndarray) -> jnp.ndarray:
    """t^com_{i:n} — eq. (6)."""
    return dev.model_nats / tx_rate(beta, bandwidth, dev, noise)


def comm_energy(dev: DeviceParams, beta: jnp.ndarray, bandwidth: jnp.ndarray,
                noise: jnp.ndarray) -> jnp.ndarray:
    """e^com_{i:n} — eq. (7)."""
    return comm_time(dev, beta, bandwidth, noise) * dev.tx_power


# ---------------------------------------------------------------------------
# Edge-level aggregation overheads, eqs. (10)-(11)
# ---------------------------------------------------------------------------

def edge_energy(dev: DeviceParams, mask: jnp.ndarray, f: jnp.ndarray,
                beta: jnp.ndarray, bandwidth: jnp.ndarray, noise: jnp.ndarray,
                lp: LearningParams) -> jnp.ndarray:
    """E^edge_{S_i} — eq. (10). ``mask`` selects S_i out of all devices."""
    per_dev = comm_energy(dev, beta, bandwidth, noise) + comp_energy(dev, f, lp)
    return lp.edge_iters * jnp.sum(jnp.where(mask, per_dev, 0.0))


def edge_delay(dev: DeviceParams, mask: jnp.ndarray, f: jnp.ndarray,
               beta: jnp.ndarray, bandwidth: jnp.ndarray, noise: jnp.ndarray,
               lp: LearningParams) -> jnp.ndarray:
    """T^edge_{S_i} — eq. (11): I * max_n (t^com + t^cmp)."""
    per_dev = comm_time(dev, beta, bandwidth, noise) + comp_time(dev, f, lp)
    return lp.edge_iters * jnp.max(jnp.where(mask, per_dev, 0.0))


def edge_cost(dev: DeviceParams, mask: jnp.ndarray, f: jnp.ndarray,
              beta: jnp.ndarray, bandwidth: jnp.ndarray, noise: jnp.ndarray,
              lp: LearningParams) -> jnp.ndarray:
    """C_i = lambda_e E^edge + lambda_t T^edge — the objective of (18)."""
    e = edge_energy(dev, mask, f, beta, bandwidth, noise, lp)
    t = edge_delay(dev, mask, f, beta, bandwidth, noise, lp)
    return lp.lambda_e * e + lp.lambda_t * t


# ---------------------------------------------------------------------------
# Cloud aggregation overheads, eqs. (12)-(16), and global objective (17)
# ---------------------------------------------------------------------------

def cloud_delay(srv: ServerParams) -> jnp.ndarray:
    """T^cloud_i — eq. (12); shape (K,)."""
    return srv.cloud_nats / srv.cloud_rate


def cloud_energy(srv: ServerParams) -> jnp.ndarray:
    """E^cloud_i — eq. (13); shape (K,)."""
    return srv.cloud_power * cloud_delay(srv)


def global_cost(dev: DeviceParams, srv: ServerParams, assignment: jnp.ndarray,
                f: jnp.ndarray, beta: jnp.ndarray, lp: LearningParams):
    """System cost of one global iteration — eqs. (15)-(17).

    Args:
      assignment: (N,) int array, device -> server index.
      f, beta:    (N,) resource decisions per device (beta is the share of
                  the *assigned* server's bandwidth).

    Returns:
      (E, T, cost) scalars.
    """
    k = srv.n_servers
    masks = jax.nn.one_hot(assignment, k, dtype=jnp.bool_).T        # (K, N)
    bw = srv.bandwidth[assignment]
    n0 = srv.noise[assignment]

    per_dev_e = comm_energy(dev, beta, bw, n0) + comp_energy(dev, f, lp)
    per_dev_t = comm_time(dev, beta, bw, n0) + comp_time(dev, f, lp)

    e_edge = lp.edge_iters * jnp.sum(
        jnp.where(masks, per_dev_e[None, :], 0.0), axis=1)          # (K,)
    t_edge = lp.edge_iters * jnp.max(
        jnp.where(masks, per_dev_t[None, :], 0.0), axis=1)          # (K,)

    energy = jnp.sum(e_edge + cloud_energy(srv))                    # eq. (15)
    delay = jnp.max(t_edge + cloud_delay(srv))                      # eq. (16)
    return energy, delay, lp.lambda_e * energy + lp.lambda_t * delay


# ---------------------------------------------------------------------------
# Section-III constants (A_n, B_n, D_n, E_n, W) for problem (18)
# ---------------------------------------------------------------------------

@_register
@dataclass
class RAConstants:
    """Constants of problem (18). Fields are (N,) arrays except scalar ``w``.

      a = lambda_e I d_n p_n / (B_i ln(1 + h p/N0))   [paper's A_n]
      b = lambda_e I L (alpha/2) c_n |D_n|            [paper's B_n]
      d = d_n / (B_i ln(1 + h p/N0))                  [paper's D_n]
      e = L c_n |D_n|                                 [paper's E_n]
      w = lambda_t I                                  [paper's W]
    """

    a: jnp.ndarray
    b: jnp.ndarray
    d: jnp.ndarray
    e: jnp.ndarray
    w: jnp.ndarray
    f_min: jnp.ndarray
    f_max: jnp.ndarray


def ra_constants(dev: DeviceParams, bandwidth, noise, lp: LearningParams) -> RAConstants:
    """Build the Section-III constants for one edge server's subproblem."""
    eff = bandwidth * spectral_efficiency(dev, noise)   # B_i ln(1+hp/N0)
    i_it = lp.edge_iters
    l_it = lp.local_iters
    return RAConstants(
        a=lp.lambda_e * i_it * dev.model_nats * dev.tx_power / eff,
        b=lp.lambda_e * i_it * l_it * 0.5 * dev.alpha * dev.cycles_per_iter,
        d=dev.model_nats / eff,
        e=l_it * dev.cycles_per_iter,
        w=jnp.asarray(lp.lambda_t * i_it, dtype=jnp.float32),
        f_min=dev.f_min,
        f_max=dev.f_max,
    )


def ra_objective(c: RAConstants, mask: jnp.ndarray, f: jnp.ndarray,
                 beta: jnp.ndarray) -> jnp.ndarray:
    """Objective of problem (18) given the constants (masked sum/max)."""
    per_sum = c.a / beta + c.b * jnp.square(f)
    per_max = c.d / beta + c.e / f
    return (jnp.sum(jnp.where(mask, per_sum, 0.0))
            + c.w * jnp.max(jnp.where(mask, per_max, 0.0)))
