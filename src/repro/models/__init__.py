from repro.models.config import MLAConfig, MoEConfig, ModelConfig, SSMConfig
from repro.models.model import (Model, ShapeSpec, SHAPES, build_model,
                                shape_applicable)

__all__ = ["MLAConfig", "MoEConfig", "ModelConfig", "SSMConfig", "Model",
           "ShapeSpec", "SHAPES", "build_model", "shape_applicable"]
