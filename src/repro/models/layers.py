"""Shared neural-net building blocks (pure JAX, parameter dicts).

Parameters are nested dicts of jnp arrays. Every block is a pair of plain
functions: ``<block>_init(rng, ...) -> params`` and
``<block>(params, x, ...) -> y``. Per-layer parameters are *stacked* along a
leading layer axis by the model builders and consumed under ``lax.scan`` so
that deep configs (64 layers) lower to compact HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(rng, d_in: int, d_out: int, *, scale: float | None = None,
               bias: bool = False, dtype=jnp.float32):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(rng, (d_in, d_out), dtype) * scale
    if bias:
        return {"w": w, "b": jnp.zeros((d_out,), dtype)}
    return {"w": w}


def dense(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def embedding_init(rng, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(rng, (vocab, d), dtype) * 0.02}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Tied read-out: logits = x @ table^T (activation dtype; the loss
    upcasts elementwise inside its reductions — materializing f32 logits
    would double the dominant memory-bound tensor of the train step)."""
    return x @ params["table"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def norm_init(d: int, *, kind: str = "rmsnorm", parametric: bool = True,
              dtype=jnp.float32):
    p = {}
    if parametric:
        p["scale"] = jnp.ones((d,), dtype)
        if kind == "layernorm":
            p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(params, x, *, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        xf = xf - jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if "scale" in params:
        y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_heads(x, scale, eps: float = 1e-6):
    """Per-head qk-norm (qwen3): x (..., H, hd), scale (hd,).

    Statistics in f32; the normalized product is emitted in x.dtype so no
    f32 activation tensor survives into the backward pass."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(rng, d: int, d_ff: int, *, kind: str = "swiglu", dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    if kind == "swiglu":
        return {"wi": dense_init(k1, d, d_ff, dtype=dtype),
                "wg": dense_init(k2, d, d_ff, dtype=dtype),
                "wo": dense_init(k3, d_ff, d, dtype=dtype)}
    return {"wi": dense_init(k1, d, d_ff, dtype=dtype),
            "wo": dense_init(k2, d_ff, d, dtype=dtype)}


def mlp(params, x, *, kind: str = "swiglu"):
    from repro.models import pjit_hints
    if kind == "swiglu":
        h = jax.nn.silu(dense(params["wg"], x)) * dense(params["wi"], x)
    else:
        h = jax.nn.gelu(dense(params["wi"], x))
    if h.ndim == 3:
        h = pjit_hints.shard_ffn(h)
    return dense(params["wo"], h)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S).

    The rotation tables are computed in f32 then cast to x.dtype so the
    elementwise math stays in the activation dtype — f32 intermediates here
    double the backward's activation traffic for zero benefit (the tables
    are position-only constants).
    """
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                        # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)        # (..., S, 1, ·)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def sinusoidal_positions(seq_len: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed positional embeddings, (S, d)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / (10_000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE in f32. logits (..., V), labels (...) int.

    The gold logit is selected with an iota-compare + masked sum rather than
    take_along_axis: a vocab-sharded logits tensor then reduces to a tiny
    (B, S) all-reduce under GSPMD instead of an all-gather of the logits.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    onehot = (vocab_iota == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
