"""Decoder-only LM assembly for all causal families.

Layer parameters are stacked along a leading axis and consumed by
``lax.scan`` so 64-layer configs lower to compact HLO. Heterogeneous parts
(leading dense layers of MoE models, zamba2's weight-tied shared attention
block) sit outside the homogeneous stack.

Families handled here: dense (olmo/qwen2/qwen3), moe (kimi-k2),
moe+mla (deepseek-v2-lite), ssm (mamba2), hybrid (zamba2), vlm backbone
(internvl2 — vision embeddings prepended). The encoder-decoder family
(whisper) lives in :mod:`repro.models.encdec`.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.attention import (attention, attention_decode,
                                    attention_init, init_kv_cache,
                                    init_mla_cache, mla_attention, mla_decode,
                                    mla_init)
from repro.models.layers import (apply_norm, cross_entropy, embed,
                                 embedding_init, mlp, mlp_init, norm_init,
                                 unembed, dense_init, dense)
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import init_ssm_cache, ssm_apply, ssm_decode, ssm_init


def _norm_params(cfg):
    return norm_init(cfg.d_model, kind=cfg.norm_type,
                     parametric=not cfg.nonparametric_norm)


def _apply_norm(cfg, p, x):
    return apply_norm(p, x, kind=cfg.norm_type)


# ---------------------------------------------------------------------------
# Homogeneous block (the scanned stack)
# ---------------------------------------------------------------------------

def block_init(rng, cfg):
    """One layer of the homogeneous stack, structure fixed by cfg.family."""
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {}
    if cfg.family in ("ssm", "hybrid"):
        p["norm1"] = _norm_params(cfg)
        p["ssm"] = ssm_init(k1, cfg)
        return p
    p["norm1"] = _norm_params(cfg)
    p["norm2"] = _norm_params(cfg)
    if cfg.mla is not None:
        p["attn"] = mla_init(k1, cfg)
    else:
        p["attn"] = attention_init(k1, cfg)
    if cfg.moe is not None:
        p["ffn"] = moe_init(k2, cfg)
    else:
        p["ffn"] = mlp_init(k2, cfg.d_model, cfg.d_ff, kind=cfg.mlp_type)
    return p


def block_apply(params, cfg, x, aux):
    from repro.models import pjit_hints
    x = pjit_hints.shard_batch(x)
    if cfg.family in ("ssm", "hybrid"):
        return x + ssm_apply(params["ssm"], cfg,
                             _apply_norm(cfg, params["norm1"], x)), aux
    h = _apply_norm(cfg, params["norm1"], x)
    if cfg.mla is not None:
        h = mla_attention(params["attn"], cfg, h)
    else:
        h = attention(params["attn"], cfg, h, causal=True, rope=cfg.use_rope)
    x = x + h
    h = _apply_norm(cfg, params["norm2"], x)
    if cfg.moe is not None:
        h, a = moe_apply(params["ffn"], cfg, h)
        aux = aux + a
    else:
        h = mlp(params["ffn"], h, kind=cfg.mlp_type)
    return x + h, aux


def dense_block_init(rng, cfg):
    """Leading dense layer of a MoE model (kimi/deepseek layer 0)."""
    k1, k2 = jax.random.split(rng)
    p = {"norm1": _norm_params(cfg), "norm2": _norm_params(cfg)}
    p["attn"] = mla_init(k1, cfg) if cfg.mla is not None else \
        attention_init(k1, cfg)
    d_ff = cfg.d_ff if cfg.d_ff else 4 * cfg.d_model
    p["ffn"] = mlp_init(k2, cfg.d_model, d_ff, kind=cfg.mlp_type)
    return p


def dense_block_apply(params, cfg, x):
    h = _apply_norm(cfg, params["norm1"], x)
    if cfg.mla is not None:
        h = mla_attention(params["attn"], cfg, h)
    else:
        h = attention(params["attn"], cfg, h, causal=True, rope=cfg.use_rope)
    x = x + h
    h = _apply_norm(cfg, params["norm2"], x)
    return x + mlp(params["ffn"], h, kind=cfg.mlp_type)


def shared_attn_init(rng, cfg):
    """Zamba2's weight-tied attention(+MLP) block."""
    k1, k2 = jax.random.split(rng)
    return {"norm1": _norm_params(cfg), "norm2": _norm_params(cfg),
            "attn": attention_init(k1, cfg),
            "ffn": mlp_init(k2, cfg.d_model, cfg.d_ff, kind=cfg.mlp_type)}


def shared_attn_apply(params, cfg, x):
    x = x + attention(params["attn"], cfg,
                      _apply_norm(cfg, params["norm1"], x),
                      causal=True, rope=cfg.use_rope)
    return x + mlp(params["ffn"], _apply_norm(cfg, params["norm2"], x),
                   kind=cfg.mlp_type)


# ---------------------------------------------------------------------------
# LM init / forward
# ---------------------------------------------------------------------------

def _n_stack_layers(cfg) -> int:
    n_dense = cfg.moe.n_dense_layers if cfg.moe is not None else 0
    return cfg.n_layers - n_dense


def lm_init(cfg, rng):
    k_embed, k_blocks, k_dense, k_shared, k_out = jax.random.split(rng, 5)
    n_stack = _n_stack_layers(cfg)
    params = {
        "embed": embedding_init(k_embed, cfg.vocab_size, cfg.d_model),
        "blocks": jax.vmap(lambda k: block_init(k, cfg))(
            jax.random.split(k_blocks, n_stack)),
        "final_norm": _norm_params(cfg),
    }
    if cfg.moe is not None and cfg.moe.n_dense_layers:
        params["dense_blocks"] = [
            dense_block_init(k, cfg)
            for k in jax.random.split(k_dense, cfg.moe.n_dense_layers)]
    if cfg.family == "hybrid" and cfg.hybrid_attn_period:
        params["shared_attn"] = shared_attn_init(k_shared, cfg)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                       scale=cfg.d_model ** -0.5)
    return params


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat != "none" else fn


def _layer_slice(params, i):
    return jax.tree.map(lambda p: p[i], params)


def _run_stack(params, cfg, x):
    """Run the homogeneous stack: lax.scan normally, an unrolled Python loop
    when cfg.scan_layers=False (dry-run cost analysis — XLA's cost model
    counts while-loop bodies exactly once). Returns (x, aux)."""
    aux0 = jnp.zeros((), jnp.float32)
    n_stack = _n_stack_layers(cfg)

    if cfg.family == "hybrid" and cfg.hybrid_attn_period:
        period = cfg.hybrid_attn_period
        assert n_stack % period == 0
        n_groups = n_stack // period
        grouped = jax.tree.map(
            lambda p: p.reshape(n_groups, period, *p.shape[1:]),
            params["blocks"])
        shared = params["shared_attn"]

        def group_body(carry, group_params):
            h, aux = carry

            def layer_body(c, lp):
                hh, a = block_apply(lp, cfg, c[0], c[1])
                return (hh, a), None

            if cfg.scan_layers:
                (h, aux), _ = jax.lax.scan(
                    _maybe_remat(cfg, layer_body), (h, aux), group_params)
            else:
                body = _maybe_remat(cfg, lambda c, lp: layer_body(c, lp)[0])
                for i in range(period):
                    h, aux = body((h, aux), _layer_slice(group_params, i))
            h = shared_attn_apply(shared, cfg, h)
            return (h, aux), None

        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(group_body, (x, aux0), grouped)
        else:
            aux = aux0
            for g in range(n_groups):
                (x, aux), _ = group_body((x, aux), _layer_slice(grouped, g))
        return x, aux

    def layer_body(carry, layer_params):
        h, aux = carry
        h, aux = block_apply(layer_params, cfg, h, aux)
        return (h, aux), None

    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(_maybe_remat(cfg, layer_body), (x, aux0),
                                   params["blocks"])
    else:
        body = _maybe_remat(cfg, lambda c, lp: layer_body(c, lp)[0])
        x, aux = x, aux0
        for i in range(n_stack):
            x, aux = body((x, aux), _layer_slice(params["blocks"], i))
    return x, aux


def lm_forward(params, cfg, tokens, *, prefix_embeds=None):
    """tokens: (B, S) int32. prefix_embeds: (B, P, d) prepended (VLM stub).

    Returns (logits (B, S[+P], V), aux_loss scalar).
    """
    from repro.models import pjit_hints
    x = embed(params["embed"], tokens).astype(
        jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = pjit_hints.shard_batch(x)
    if cfg.moe is not None and cfg.moe.n_dense_layers:
        for dp in params["dense_blocks"]:
            x = dense_block_apply(dp, cfg, x)
    x, aux = _run_stack(params, cfg, x)
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["unembed"], x)
    return pjit_hints.shard_logits(logits), aux


def lm_loss(params, cfg, batch):
    """batch: {tokens (B, S+1)[, prefix_embeds, loss_mask]} -> scalar loss."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, aux = lm_forward(params, cfg, inputs,
                             prefix_embeds=batch.get("prefix_embeds"))
    if batch.get("prefix_embeds") is not None:
        logits = logits[:, batch["prefix_embeds"].shape[1]:]
    loss = cross_entropy(logits, labels, batch.get("loss_mask"))
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def _layer_cache_init(cfg, batch, max_len, dtype):
    if cfg.family in ("ssm", "hybrid"):
        return init_ssm_cache(cfg, batch, jnp.float32)
    if cfg.mla is not None:
        return init_mla_cache(cfg, batch, max_len, dtype)
    return init_kv_cache(cfg, batch, max_len, dtype)


def lm_decode_init(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Build the full decode cache pytree (stacked over stack layers)."""
    n_stack = _n_stack_layers(cfg)
    stack = jax.vmap(lambda _: _layer_cache_init(cfg, batch, max_len, dtype)
                     )(jnp.arange(n_stack))
    cache = {"stack": stack, "position": jnp.zeros((batch,), jnp.int32)}
    if cfg.moe is not None and cfg.moe.n_dense_layers:
        cache["dense"] = [_layer_cache_init(cfg, batch, max_len, dtype)
                          for _ in range(cfg.moe.n_dense_layers)]
    if cfg.family == "hybrid" and cfg.hybrid_attn_period:
        n_apps = _n_stack_layers(cfg) // cfg.hybrid_attn_period
        cache["shared"] = jax.vmap(
            lambda _: init_kv_cache(cfg, batch, max_len, dtype)
        )(jnp.arange(n_apps))
    return cache


def _block_decode(params, cfg, x, layer_cache, position, *, moe_ffn=None):
    if moe_ffn is None:
        moe_ffn = cfg.moe is not None
    if cfg.family in ("ssm", "hybrid"):
        h, new = ssm_decode(params["ssm"], cfg,
                            _apply_norm(cfg, params["norm1"], x), layer_cache)
        return x + h, new
    h = _apply_norm(cfg, params["norm1"], x)
    if cfg.mla is not None:
        h, new = mla_decode(params["attn"], cfg, h, layer_cache)
    else:
        h, new = attention_decode(params["attn"], cfg, h, layer_cache,
                                  rope=cfg.use_rope)
    x = x + h
    h = _apply_norm(cfg, params["norm2"], x)
    if moe_ffn:
        h, _ = moe_apply(params["ffn"], cfg, h)
    else:
        h = mlp(params["ffn"], h, kind=cfg.mlp_type)
    return x + h, new


def lm_decode_step(params, cfg, cache, tokens):
    """One decode step. tokens: (B,) int32 -> (logits (B, V), new cache)."""
    x = embed(params["embed"], tokens[:, None]).astype(
        jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    position = cache["position"]
    new_cache = {"position": position + 1}

    if cfg.moe is not None and cfg.moe.n_dense_layers:
        new_dense = []
        for dp, dc in zip(params["dense_blocks"], cache["dense"]):
            x, nc = _block_decode(dp, cfg, x, dc, position, moe_ffn=False)
            new_dense.append(nc)
        new_cache["dense"] = new_dense

    if cfg.family == "hybrid" and cfg.hybrid_attn_period:
        period = cfg.hybrid_attn_period
        n_stack = _n_stack_layers(cfg)
        n_groups = n_stack // period
        grouped = jax.tree.map(
            lambda p: p.reshape(n_groups, period, *p.shape[1:]),
            params["blocks"])
        gcache = jax.tree.map(
            lambda c: c.reshape(n_groups, period, *c.shape[1:]),
            cache["stack"])
        shared = params["shared_attn"]

        def group_body(x, xs):
            gp, gc, sc = xs

            def layer_body(h, ls):
                lp, lc = ls
                h, nc = _block_decode(lp, cfg, h, lc, position)
                return h, nc

            x, new_gc = jax.lax.scan(layer_body, x, (gp, gc))
            h = _apply_norm(cfg, shared["norm1"], x)
            h, new_sc = attention_decode(shared["attn"], cfg, h, sc,
                                         rope=cfg.use_rope)
            x = x + h
            x = x + mlp(shared["ffn"],
                        _apply_norm(cfg, shared["norm2"], x),
                        kind=cfg.mlp_type)
            return x, (new_gc, new_sc)

        x, (new_stack, new_shared) = jax.lax.scan(
            group_body, x, (grouped, gcache, cache["shared"]))
        new_cache["stack"] = jax.tree.map(
            lambda c: c.reshape(n_stack, *c.shape[2:]), new_stack)
        new_cache["shared"] = new_shared
    else:
        def layer_body(x, xs):
            lp, lc = xs
            x, nc = _block_decode(lp, cfg, x, lc, position)
            return x, nc

        if cfg.scan_layers:
            x, new_stack = jax.lax.scan(layer_body, x,
                                        (params["blocks"], cache["stack"]))
        else:
            n_stack = _n_stack_layers(cfg)
            new_layers = []
            for i in range(n_stack):
                x, nc = layer_body(x, (_layer_slice(params["blocks"], i),
                                       _layer_slice(cache["stack"], i)))
                new_layers.append(nc)
            new_stack = jax.tree.map(lambda *ls: jnp.stack(ls), *new_layers)
        new_cache["stack"] = new_stack

    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["unembed"], x)
    return logits[:, 0], new_cache
