"""Mixture-of-Experts with sort-based capacity dispatch.

Designed for large expert counts (kimi-k2: 384 routed experts) where the
GShard one-hot dispatch einsum (tokens x experts x capacity) is infeasible.
Tokens are ranked into per-expert slots via a stable sort; over-capacity
tokens are dropped (their residual path passes through untouched, plus any
shared experts). Expert FFNs run as one batched einsum over the
(E, capacity, d) buffer, which shards cleanly: E over the ``model`` mesh
axis (expert parallelism), capacity over ``data``.

Router in f32; auxiliary load-balancing loss returned to the caller.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, mlp, mlp_init


def moe_init(rng, cfg, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    kr, ke, ks = jax.random.split(rng, 3)

    def expert_init(k):
        return mlp_init(k, d, m.d_expert, kind=cfg.mlp_type, dtype=dtype)

    p = {
        "router": dense_init(kr, d, m.n_experts, scale=0.02, dtype=dtype),
        "experts": jax.vmap(expert_init)(jax.random.split(ke, m.n_experts)),
    }
    if m.n_shared:
        p["shared"] = mlp_init(ks, d, m.d_expert * m.n_shared,
                               kind=cfg.mlp_type, dtype=dtype)
    return p


def _expert_ffn(experts, buf, kind: str):
    """buf: (E, C, d) -> (E, C, d) through per-expert FFNs."""
    def matmul(w, x):           # w: (E, a, b), x: (E, C, a)
        return jnp.einsum("eca,eab->ecb", x, w.astype(x.dtype))

    if kind == "swiglu":
        h = jax.nn.silu(matmul(experts["wg"]["w"], buf)) * \
            matmul(experts["wi"]["w"], buf)
    else:
        h = jax.nn.gelu(matmul(experts["wi"]["w"], buf))
    return matmul(experts["wo"]["w"], h)


def moe_apply(params, cfg, x):
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    tokens = x.reshape(t, d)

    logits = dense(params["router"], tokens.astype(jnp.float32))   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, m.top_k)                      # (T, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], m.n_experts), axis=0)
    aux = m.n_experts * jnp.sum(me * ce)

    tk = t * m.top_k
    flat_ids = ids.reshape(tk)
    flat_gate = gate.reshape(tk)
    token_idx = jnp.arange(tk) // m.top_k

    capacity = max(int(math.ceil(tk * m.capacity_factor / m.n_experts)), 4)

    # slot of each (token, expert) pair within its expert, via stable sort
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    pos_in_group = jnp.arange(tk) - jnp.searchsorted(
        sorted_ids, sorted_ids, side="left")
    slot = jnp.zeros(tk, jnp.int32).at[order].set(pos_in_group.astype(jnp.int32))

    # scatter into the expert buffer; over-capacity slots are dropped
    from repro.models import pjit_hints
    buf = jnp.zeros((m.n_experts, capacity, d), x.dtype)
    buf = buf.at[flat_ids, slot].set(tokens[token_idx], mode="drop")
    buf = pjit_hints.shard_experts(buf)

    out_buf = _expert_ffn(params["experts"], buf, cfg.mlp_type)
    out_buf = pjit_hints.shard_experts(out_buf)

    gathered = out_buf.at[flat_ids, slot].get(
        mode="fill", fill_value=0.0)                               # (Tk, d)
    y = jnp.sum((gathered * flat_gate[:, None].astype(gathered.dtype))
                .reshape(t, m.top_k, d), axis=1)

    if m.n_shared:
        y = y + mlp(params["shared"], tokens, kind=cfg.mlp_type)
    return y.reshape(b, s, d), aux
