"""Model facade: a uniform init/loss/decode interface over all families,
plus input-shape builders for the assigned (arch x shape) grid."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "train"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# pure full-attention archs skip long_500k (no sub-quadratic mechanism);
# see DESIGN.md §Arch-applicability.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True


class Model:
    """Family-dispatched facade used by the FL runtime, launcher and dryrun."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters ---------------------------------------------------------

    def init(self, rng):
        if self.cfg.family == "encdec":
            return encdec.encdec_init(self.cfg, rng)
        return transformer.lm_init(self.cfg, rng)

    # -- training -----------------------------------------------------------

    def loss(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec.encdec_loss(params, self.cfg, batch)
        return transformer.lm_loss(params, self.cfg, batch)

    def logits(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec.encdec_forward(params, self.cfg, batch["frames"],
                                         batch["tokens"][:, :-1])
        out, _ = transformer.lm_forward(
            params, self.cfg, batch["tokens"][:, :-1],
            prefix_embeds=batch.get("prefix_embeds"))
        return out

    # -- serving ------------------------------------------------------------

    def decode_init(self, params, batch: dict, max_len: int,
                    dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return encdec.encdec_decode_init(params, self.cfg,
                                             batch["frames"], max_len, dtype)
        bsz = batch["tokens"].shape[0]
        return transformer.lm_decode_init(self.cfg, bsz, max_len, dtype)

    def decode_step(self, params, cache, tokens):
        if self.cfg.family == "encdec":
            return encdec.encdec_decode_step(params, self.cfg, cache, tokens)
        return transformer.lm_decode_step(params, self.cfg, cache, tokens)

    # -- shape builders (ShapeDtypeStruct stand-ins; no allocation) ----------

    def batch_specs(self, shape: ShapeSpec, *, batch_override: int | None = None):
        """Training/prefill batch ShapeDtypeStructs for ``jit.lower``."""
        cfg = self.cfg
        b = batch_override or shape.global_batch
        specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len + 1),
                                                jnp.int32)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
        return specs

    def decode_specs(self, shape: ShapeSpec, *, batch_override: int | None = None):
        """(cache_specs, token_spec) for serve_step lowering."""
        cfg = self.cfg
        b = batch_override or shape.global_batch
        max_len = shape.seq_len
        if cfg.family == "encdec":
            # cache depends on params: derive via eval_shape over decode_init
            params_spec = jax.eval_shape(
                lambda r: encdec.encdec_init(cfg, r), jax.random.key(0))
            frames_spec = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
            cache = jax.eval_shape(
                lambda p, f: encdec.encdec_decode_init(p, cfg, f, max_len),
                params_spec, frames_spec)
        else:
            cache = jax.eval_shape(
                lambda: transformer.lm_decode_init(cfg, b, max_len))
        return cache, jax.ShapeDtypeStruct((b,), jnp.int32)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
