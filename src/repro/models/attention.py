"""Attention: blocked (FlashAttention-style) training path, cached decode
path, GQA / qk-norm / QKV-bias variants, and DeepSeek MLA.

The training path processes queries in ``block_q`` tiles (a Python loop —
unrolled HLO, one compact scan per tile) and keys/values in ``block_kv``
tiles under an online-softmax ``lax.scan``, so no (Sq, Skv) score matrix is
ever materialized. With ``schedule="triangle"`` (default for causal), each
query tile only scans the key tiles it can actually see — halving causal
attention FLOPs vs. masked-full computation. ``schedule="full"`` keeps the
naive behaviour and is the §Perf baseline.

All shapes are (batch, seq, heads, head_dim); softmax statistics in f32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, dense_init, rms_norm_heads

_NEG_INF = -1e30


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def blocked_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                      block_kv: int = 1024, schedule: str = "triangle",
                      q_offset: int = 0, softmax_scale: float | None = None,
                      vjp_mode: str = "autodiff"):
    """Online-softmax attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) with Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (for cross-chunk causal decode).
    """
    from repro.models import pjit_hints
    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5

    if g > 1:
        # expand kv to full query heads (TP kv-replication): scores then
        # shard cleanly over the head axis instead of replicating over
        # model because hkv < tp. No extra per-device memory under TP.
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = pjit_hints.shard_heads(k)
        v = pjit_hints.shard_heads(v)

    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    sq_orig, skv_orig = sq, skv
    if sq % block_q:
        q = jnp.pad(q, ((0, 0), (0, block_q - sq % block_q), (0, 0), (0, 0)))
        sq = q.shape[1]
    if skv % block_kv:
        pad = block_kv - skv % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv = k.shape[1]
    nq, nk = sq // block_q, skv // block_kv

    if (vjp_mode == "flash" and q_offset == 0 and sq == sq_orig
            and skv == skv_orig):
        return pjit_hints.shard_heads(
            _flash_mha(q, k, v, causal, block_q, block_kv, schedule, scale))

    qr = (q * scale).astype(q.dtype)
    kb = k.reshape(b, nk, block_kv, hq, hd)
    vb = v.reshape(b, nk, block_kv, hq, hd)

    # padded kv positions get an id beyond every real query position; for
    # the non-causal path they are masked explicitly below.
    kv_pos = jnp.arange(skv).reshape(nk, block_kv)
    kv_valid = (jnp.arange(skv) < skv_orig).reshape(nk, block_kv)

    out_tiles = []
    for iq in range(nq):
        q_tile = qr[:, iq * block_q:(iq + 1) * block_q]      # (B, bq, H, hd)
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)
        if causal and schedule == "triangle":
            hi = min(nk, _cdiv(q_offset + (iq + 1) * block_q, block_kv))
        else:
            hi = nk

        def body(carry, xs):
            acc, m, l = carry
            k_blk, v_blk, pos_blk, valid_blk = xs
            # scores: (B, H, bq, bkv), sharded over heads under TP
            s = jnp.einsum("bqhd,bkhd->bhqk", q_tile, k_blk,
                           preferred_element_type=jnp.float32)
            s = pjit_hints.shard_scores(s)
            if causal:
                mask = pos_blk[None, :] <= q_pos[:, None]    # (bq, bkv)
                s = jnp.where(mask[None, None], s, _NEG_INF)
            else:
                s = jnp.where(valid_blk[None, None, None, :], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, hq, block_q, hd), jnp.float32)
        m0 = jnp.full((b, hq, block_q), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, block_q), jnp.float32)
        xs = (kb[:, :hi].swapaxes(0, 1), vb[:, :hi].swapaxes(0, 1),
              kv_pos[:hi], kv_valid[:hi])
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), xs)
        tile = acc / jnp.maximum(l, 1e-30)[..., None]        # (B, H, bq, hd)
        out_tiles.append(tile.transpose(0, 2, 1, 3))
    out = jnp.concatenate(out_tiles, axis=1)
    return pjit_hints.shard_heads(out[:, :sq_orig].astype(q.dtype))


# ---------------------------------------------------------------------------
# Flash-style custom VJP (beyond-paper §Perf optimization)
#
# Differentiating the online-softmax scan with autodiff saves the (acc, m, l)
# carries of every kv step of every q tile — O(n_tiles^2) f32 buffers that
# dominate the train step's HBM traffic. The custom VJP instead saves only
# (q, k, v, out, lse) and recomputes the probabilities tile-by-tile in the
# backward — the standard FlashAttention recomputation, here as the pure-JAX
# lowering used by the dry-run (the Pallas kernel is the TPU-native twin).
# ---------------------------------------------------------------------------

def _tiles(x, n, size):
    return x.reshape(x.shape[0], n, size, *x.shape[2:])


def _fa_forward(q, k, v, causal, block_q, block_kv, schedule, scale):
    """Tiled forward returning (out, lse). Shapes (B, S, H, hd), MHA only
    (kv already expanded)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    nq, nk = sq // block_q, skv // block_kv
    qs = (q * scale).astype(q.dtype)
    kb = _tiles(k, nk, block_kv)
    vb = _tiles(v, nk, block_kv)
    kv_pos = jnp.arange(skv).reshape(nk, block_kv)

    outs, lses = [], []
    for iq in range(nq):
        q_tile = qs[:, iq * block_q:(iq + 1) * block_q]
        q_pos = iq * block_q + jnp.arange(block_q)
        hi = (min(nk, _cdiv((iq + 1) * block_q, block_kv))
              if causal and schedule == "triangle" else nk)

        def body(carry, xs):
            acc, m, l = carry
            k_blk, v_blk, pos_blk = xs
            s = jnp.einsum("bqhd,bkhd->bhqk", q_tile, k_blk,
                           preferred_element_type=jnp.float32)
            if causal:
                s = jnp.where((pos_blk[None, :] <= q_pos[:, None])[None, None],
                              s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            return (acc * corr[..., None] + pv, m_new, l), None

        acc0 = jnp.zeros((b, h, block_q, hd), jnp.float32)
        m0 = jnp.full((b, h, block_q), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            body, (acc0, m0, l0),
            (kb[:, :hi].swapaxes(0, 1), vb[:, :hi].swapaxes(0, 1),
             kv_pos[:hi]))
        l = jnp.maximum(l, 1e-30)
        outs.append((acc / l[..., None]).transpose(0, 2, 1, 3))
        lses.append((m + jnp.log(l)).transpose(0, 2, 1))     # (B, bq, H)
    out = jnp.concatenate(outs, axis=1).astype(q.dtype)
    lse = jnp.concatenate(lses, axis=1)                      # (B, Sq, H) f32
    return out, lse


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_mha(q, k, v, causal, block_q, block_kv, schedule, scale):
    return _fa_forward(q, k, v, causal, block_q, block_kv, schedule, scale)[0]


def _flash_mha_fwd(q, k, v, causal, block_q, block_kv, schedule, scale):
    out, lse = _fa_forward(q, k, v, causal, block_q, block_kv, schedule,
                           scale)
    return out, (q, k, v, out, lse)


def _flash_mha_bwd(causal, block_q, block_kv, schedule, scale, res, g):
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    nq, nk = sq // block_q, skv // block_kv
    qs = (q * scale).astype(q.dtype)
    kb = _tiles(k, nk, block_kv)
    vb = _tiles(v, nk, block_kv)
    kv_pos = jnp.arange(skv).reshape(nk, block_kv)

    # D_i = rowsum(dout * out): the softmax-backward diagonal term
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).transpose(0, 2, 1)               # (B, H, Sq)

    dq = jnp.zeros_like(q, dtype=jnp.float32)
    dk = jnp.zeros((b, h, skv, hd), jnp.float32)
    dv = jnp.zeros((b, h, skv, hd), jnp.float32)

    for iq in range(nq):
        sl = slice(iq * block_q, (iq + 1) * block_q)
        q_tile = qs[:, sl]
        g_tile = g[:, sl].astype(jnp.float32).transpose(0, 2, 1, 3)
        lse_tile = lse[:, sl].transpose(0, 2, 1)              # (B, H, bq)
        d_tile = delta[:, :, sl]                              # (B, H, bq)
        q_pos = iq * block_q + jnp.arange(block_q)
        hi = (min(nk, _cdiv((iq + 1) * block_q, block_kv))
              if causal and schedule == "triangle" else nk)

        def body(dq_acc, xs):
            k_blk, v_blk, pos_blk, ik = xs
            s = jnp.einsum("bqhd,bkhd->bhqk", q_tile, k_blk,
                           preferred_element_type=jnp.float32)
            if causal:
                s = jnp.where((pos_blk[None, :] <= q_pos[:, None])[None, None],
                              s, _NEG_INF)
            p = jnp.exp(s - lse_tile[..., None])              # (B,H,bq,bkv)
            dp = jnp.einsum("bhqd,bkhd->bhqk", g_tile,
                            v_blk.astype(jnp.float32))
            ds = p * (dp - d_tile[..., None])                 # (B,H,bq,bkv)
            dq_blk = jnp.einsum("bhqk,bkhd->bqhd", ds,
                                k_blk.astype(jnp.float32)) * scale
            # q_tile is pre-scaled, so ds^T @ q_tile already carries `scale`
            dk_blk = jnp.einsum("bhqk,bqhd->bhkd", ds,
                                q_tile.astype(jnp.float32))
            dv_blk = jnp.einsum("bhqk,bhqd->bhkd", p, g_tile)
            return dq_acc + dq_blk, (dk_blk, dv_blk, ik)

        xs = (kb[:, :hi].swapaxes(0, 1), vb[:, :hi].swapaxes(0, 1),
              kv_pos[:hi], jnp.arange(hi))
        dq_tile, (dk_blks, dv_blks, iks) = jax.lax.scan(
            body, jnp.zeros((b, block_q, h, hd), jnp.float32), xs)
        dq = dq.at[:, sl].add(dq_tile.astype(dq.dtype))
        # scatter-add the kv-tile contributions
        dk_contrib = dk_blks.transpose(1, 2, 0, 3, 4).reshape(
            b, h, hi * block_kv, hd)
        dv_contrib = dv_blks.transpose(1, 2, 0, 3, 4).reshape(
            b, h, hi * block_kv, hd)
        dk = dk.at[:, :, :hi * block_kv].add(dk_contrib)
        dv = dv.at[:, :, :hi * block_kv].add(dv_contrib)

    dk = dk.transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv.transpose(0, 2, 1, 3).astype(v.dtype)
    return dq.astype(q.dtype), dk, dv


_flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


def cached_attention(q, k_cache, v_cache, length):
    """Single-step decode attention against a (possibly padded) KV cache.

    q: (B, 1, Hq, hd); caches: (B, S_max, Hkv, hd); ``length``: valid prefix.
    """
    b, _, hq, hd = q.shape
    _, s_max, hkv, _ = k_cache.shape
    g = hq // hkv
    qr = q.reshape(b, hkv, g, hd) * hd ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32)
    mask = jnp.arange(s_max)[None, :] < length[:, None]      # (B, S_max)
    s = jnp.where(mask[:, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Standard (GQA) attention block
# ---------------------------------------------------------------------------

def attention_init(rng, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko, _ = jax.random.split(rng, 5)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params, cfg, x, positions, *, rope: bool = True):
    from repro.models import pjit_hints
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense(params["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(params["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    q, k, v = (pjit_hints.shard_heads(t) for t in (q, k, v))
    if cfg.qk_norm:
        q = rms_norm_heads(q, params["q_norm"])
        k = rms_norm_heads(k, params["k_norm"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(params, cfg, x, *, causal: bool = True, positions=None,
              schedule: str | None = None, kv_override=None, rope: bool = True):
    """Full-sequence attention (training / prefill).

    ``kv_override``: (k, v) pair for cross-attention (encoder-decoder);
    queries still come from x.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions, rope=rope)
    if kv_override is not None:
        k, v = kv_override
    sched = schedule or ("triangle" if causal else "full")
    if cfg.use_flash_kernel:
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal,
                              block_q=cfg.attn_block_q,
                              block_kv=cfg.attn_block_kv)
    else:
        out = blocked_attention(q, k, v, causal=causal, schedule=sched,
                                block_q=cfg.attn_block_q,
                                block_kv=cfg.attn_block_kv,
                                vjp_mode=cfg.attn_vjp)
    return dense(params["wo"], out.reshape(b, s, -1))


def cross_kv(params, cfg, enc_out):
    """Pre-compute the cross-attention K/V from encoder output."""
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = dense(params["wk"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(params["wv"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
    return k, v


def attention_decode(params, cfg, x, cache, *, rope: bool = True):
    """One decode step. x: (B, 1, d); cache dict with k, v (B, S_max, Hkv, hd)
    and scalar/vec ``length``. Returns (out, new_cache)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    length = cache["length"]                                  # (B,) int32
    q, k, v = _project_qkv(params, cfg, x, length[:, None], rope=rope)
    # write the new kv at position `length`
    idx = length[:, None, None, None]
    onehot = (jnp.arange(cache["k"].shape[1])[None, :, None, None] == idx)
    k_cache = jnp.where(onehot, k.astype(cache["k"].dtype), cache["k"])
    v_cache = jnp.where(onehot, v.astype(cache["v"].dtype), cache["v"])
    out = cached_attention(q, k_cache, v_cache, length + 1)
    new_cache = {"k": k_cache, "v": v_cache, "length": length + 1}
    return dense(params["wo"], out.reshape(b, 1, -1)), new_cache


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# DeepSeek Multi-head Latent Attention (MLA)
# ---------------------------------------------------------------------------

def mla_init(rng, cfg, dtype=jnp.float32):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    kq, ka, kb, ko = jax.random.split(rng, 4)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": dense_init(kq, d, h * qk_dim, dtype=dtype),
        "wkv_a": dense_init(ka, d, m.kv_lora_rank + m.qk_rope_head_dim,
                            dtype=dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": dense_init(kb, m.kv_lora_rank,
                            h * (m.qk_nope_head_dim + m.v_head_dim), dtype=dtype),
        "wo": dense_init(ko, h * m.v_head_dim, d, dtype=dtype),
    }


def _mla_qkv(params, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim

    q = dense(params["wq"], x).reshape(b, s, h, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = dense(params["wkv_a"], x)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm_heads(c_kv[..., None, :],
                          params["kv_norm"])[..., 0, :]      # (B, S, r)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)                      # (B, S, 1, rope)
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(params, cfg, x, *, positions=None, schedule=None):
    """Training / prefill MLA: expand the latent to per-head K/V."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)

    kv = dense(params["wkv_b"], c_kv).reshape(
        b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope_h = jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # pad v to the qk head dim so one blocked kernel serves both
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = blocked_attention(q_full, k_full,
                            jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                        (0, q_full.shape[-1] - v.shape[-1]))),
                            causal=True,
                            schedule=schedule or "triangle",
                            block_q=cfg.attn_block_q,
                            block_kv=cfg.attn_block_kv,
                            softmax_scale=scale)
    out = out[..., :m.v_head_dim]
    return dense(params["wo"], out.reshape(b, s, -1))


def mla_decode(params, cfg, x, cache):
    """Absorbed-matmul MLA decode: the cache stores only (c_kv, k_rope) —
    the architecture's KV-compression win."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    length = cache["length"]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, length[:, None])

    s_max = cache["c_kv"].shape[1]
    onehot = (jnp.arange(s_max)[None, :] == length[:, None])
    c_cache = jnp.where(onehot[..., None], c_kv.astype(cache["c_kv"].dtype),
                        cache["c_kv"])
    r_cache = jnp.where(onehot[..., None],
                        k_rope[:, :, 0, :].astype(cache["k_rope"].dtype),
                        cache["k_rope"])

    # absorb wkv_b's K half into the query: q_eff = q_nope @ Wk  (per head)
    wkv_b = params["wkv_b"]["w"].reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    wk = wkv_b[:, :, :m.qk_nope_head_dim]                    # (r, H, nope)
    wv = wkv_b[:, :, m.qk_nope_head_dim:]                    # (r, H, v)
    q_eff = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))               # (B,1,H,r)

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s_lat = jnp.einsum("bshr,bkr->bhk", q_eff,
                       c_cache.astype(jnp.float32)) * scale
    s_rope = jnp.einsum("bshn,bkn->bhk", q_rope.astype(jnp.float32),
                        r_cache.astype(jnp.float32)) * scale
    scores = s_lat + s_rope
    mask = jnp.arange(s_max)[None, :] < (length + 1)[:, None]
    scores = jnp.where(mask[:, None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)                      # (B, H, S)
    ctx = jnp.einsum("bhk,bkr->bhr", p, c_cache.astype(jnp.float32))
    out = jnp.einsum("bhr,rhv->bhv", ctx, wv.astype(jnp.float32))
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    new_cache = {"c_kv": c_cache, "k_rope": r_cache, "length": length + 1}
    return dense(params["wo"], out), new_cache


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }
