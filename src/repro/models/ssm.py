"""Mamba2 — State Space Duality (SSD) blocks (arXiv:2405.21060).

The sequence dimension is processed in chunks: within a chunk the SSD
recurrence is evaluated as a masked (decay-weighted) attention-like matmul
(MXU-friendly); across chunks a compact (H, N, P) state is carried by a
``lax.scan``. Per-token decode is the plain O(1) recurrence.

Shapes: x (B, S, H, P) after the input projection reshape, B/C (B, S, G, N)
with H % G == 0, dt (B, S, H), A (H,) negative.
All SSD math runs in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init


def ssm_init(rng, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.state_size
    k_in, k_conv, k_a, k_out = jax.random.split(rng, 4)

    proj_dim = 2 * d_inner + 2 * s.n_groups * s.state_size + n_heads
    return {
        "in_proj": dense_init(k_in, d, proj_dim, dtype=dtype),
        "conv_w": jax.random.normal(k_conv, (s.conv_kernel, conv_dim), dtype)
        * (s.conv_kernel * conv_dim) ** -0.5,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "d_skip": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(k_out, d_inner, d, dtype=dtype),
    }


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    gn = s.n_groups * s.state_size
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d along seq. xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def ssd_chunked(x, dt, a, b_mat, c_mat, *, chunk: int,
                initial_state=None):
    """Chunked SSD scan (the heart of Mamba2).

    x (B,S,H,P), dt (B,S,H) [post-softplus], a (H,) negative,
    b_mat/c_mat (B,S,G,N). Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    bsz, seq, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hg = h // g
    assert seq % chunk == 0, (seq, chunk)
    nc = seq // chunk

    f32 = jnp.float32
    # chunk-major layout for the scan: (NC, B, Q, ...)
    xc = x.astype(f32).reshape(bsz, nc, chunk, h, p).swapaxes(0, 1)
    dtc = dt.astype(f32).reshape(bsz, nc, chunk, h).swapaxes(0, 1)
    bm = b_mat.astype(f32).reshape(bsz, nc, chunk, g, n).swapaxes(0, 1)
    cm = c_mat.astype(f32).reshape(bsz, nc, chunk, g, n).swapaxes(0, 1)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(state, xs):
        xq, dtq, bq, cq = xs                                # (B,Q,H,P) ...
        da = dtq * a[None, None, :]                         # (B,Q,H)
        seg = jnp.cumsum(da, axis=1)

        # intra-chunk: masked decay attention. Mask BEFORE the exp: masked
        # (future) entries have rel > 0 and exp(rel) overflows to inf, and
        # `where(mask, inf, 0)` then poisons the backward with 0 * inf.
        rel = seg[:, :, None, :] - seg[:, None, :, :]       # (B,Q,T,H)
        rel = jnp.where(causal[None, :, :, None], rel, -1e30)
        decay = jnp.exp(rel)
        scores = jnp.einsum("bqgn,btgn->bqtg", cq, bq)      # (B,Q,T,G)
        scores = jnp.repeat(scores, hg, axis=-1)            # (B,Q,T,H)
        att = scores * decay * dtq[:, None, :, :]
        y_intra = jnp.einsum("bqth,bthp->bqhp", att, xq)

        # inter-chunk: contribution of the entering state
        ch = jnp.repeat(cq, hg, axis=-2)                    # (B,Q,H,N)
        y_inter = jnp.einsum("bqh,bqhn,bhnp->bqhp",
                             jnp.exp(seg), ch, state)

        # state update
        last = seg[:, -1:, :]
        w_state = jnp.exp(last - seg) * dtq                 # (B,Q,H)
        bh = jnp.repeat(bq, hg, axis=-2)                    # (B,Q,H,N)
        states_c = jnp.einsum("bqh,bqhn,bqhp->bhnp", w_state, bh, xq)
        chunk_decay = jnp.exp(jnp.sum(da, axis=1))          # (B,H)
        new_state = state * chunk_decay[..., None, None] + states_c
        return new_state, y_intra + y_inter

    init = (jnp.zeros((bsz, h, n, p), f32) if initial_state is None
            else initial_state.astype(f32))
    final, ys = jax.lax.scan(body, init, (xc, dtc, bm, cm))
    y = ys.swapaxes(0, 1).reshape(bsz, seq, h, p)
    return y, final


def ssm_apply(params, cfg, x, *, initial_state=None, return_state=False):
    """Full-sequence Mamba2 block. x: (B, S, d) -> (B, S, d)."""
    s = cfg.ssm
    bsz, seq, d = x.shape
    d_inner = s.expand * d
    h = d_inner // s.head_dim
    gn = s.n_groups * s.state_size

    zxbcdt = dense(params["in_proj"], x)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, params["conv_w"].astype(x.dtype),
                       params["conv_b"].astype(x.dtype))
    xs, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)

    xs = xs.reshape(bsz, seq, h, s.head_dim)
    b_mat = b_mat.reshape(bsz, seq, s.n_groups, s.state_size)
    c_mat = c_mat.reshape(bsz, seq, s.n_groups, s.state_size)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    y, state = ssd_chunked(xs, dt, a, b_mat, c_mat, chunk=min(s.chunk_size, seq),
                           initial_state=initial_state)
    y = y + xs.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[
        None, None, :, None]
    y = y.reshape(bsz, seq, d_inner).astype(x.dtype)

    # gated RMSNorm (Mamba2's norm-before-out-proj)
    yz = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(yz.astype(jnp.float32)), axis=-1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
          * params["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = dense(params["out_proj"], yz)
    if return_state:
        return out, state
    return out


# ---------------------------------------------------------------------------
# O(1) decode
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    h = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.state_size
    return {
        "state": jnp.zeros((batch, h, s.state_size, s.head_dim), dtype),
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype),
    }


def ssm_decode(params, cfg, x, cache):
    """One-token step. x: (B, 1, d). Returns (y, new_cache)."""
    s = cfg.ssm
    bsz, _, d = x.shape
    d_inner = s.expand * d
    h = d_inner // s.head_dim
    gn = s.n_groups * s.state_size

    zxbcdt = dense(params["in_proj"], x)
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    # rolling conv buffer
    window = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)],
                             axis=1)                        # (B, K, C)
    w = params["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w) \
        + params["conv_b"].astype(jnp.float32)
    xbc1 = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv = window[:, 1:]

    xs, b_mat, c_mat = jnp.split(xbc1, [d_inner, d_inner + gn], axis=-1)
    xs = xs.reshape(bsz, h, s.head_dim).astype(jnp.float32)
    b_mat = b_mat.reshape(bsz, s.n_groups, s.state_size).astype(jnp.float32)
    c_mat = c_mat.reshape(bsz, s.n_groups, s.state_size).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    hg = h // s.n_groups
    bh = jnp.repeat(b_mat, hg, axis=1)                      # (B,H,N)
    ch = jnp.repeat(c_mat, hg, axis=1)
    decay = jnp.exp(dt1 * a[None, :])                       # (B,H)
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bh,bhn,bhp->bhnp", dt1, bh, xs)
    y = jnp.einsum("bhn,bhnp->bhp", ch, state) + \
        xs * params["d_skip"].astype(jnp.float32)[None, :, None]

    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    yz = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(yz.astype(jnp.float32)), axis=-1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
          * params["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = dense(params["out_proj"], yz)
    return out, {"state": state, "conv": new_conv}
