"""Architecture configuration schema for the model zoo.

One :class:`ModelConfig` describes any of the assigned families:
dense decoder-only LMs (olmo/qwen2/qwen3), MoE LMs (kimi-k2,
deepseek-v2-lite w/ MLA), encoder-decoder audio (whisper), VLM backbones
(internvl2), SSMs (mamba2) and hybrids (zamba2).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    n_dense_layers: int = 0        # leading layers that stay dense


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0           # 0 = full-rank q projection


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 128          # N
    head_dim: int = 64             # P
    n_groups: int = 1              # G (B/C parameter groups)
    conv_kernel: int = 4
    expand: int = 2                # d_inner = expand * d_model
    chunk_size: int = 256          # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0              # 0 = d_model // n_heads
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2
    nonparametric_norm: bool = False   # olmo
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    mlp_type: str = "swiglu"       # swiglu | gelu
    rope_theta: float = 10_000.0
    use_rope: bool = True          # False: whisper (learned/sinusoidal pos)
    tie_embeddings: bool = False
    max_seq_len: int = 524_288

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (zamba2): one weight-tied attention block every `period` layers
    hybrid_attn_period: int = 0

    # encoder-decoder (whisper): encoder depth; frontend supplies embeddings
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0       # e.g. 1500 post-conv audio frames

    # vlm (internvl2): frontend patch embeddings prepended to the sequence
    n_vision_tokens: int = 0

    # training-time knobs
    dtype: str = "bfloat16"
    remat: str = "block"           # none | block | full
    scan_layers: bool = True       # False: unroll (dry-run cost analysis —
                                   # XLA counts while-loop bodies once)
    attn_vjp: str = "autodiff"     # "flash": custom-VJP recompute backward
                                   # (kills the O(tiles^2) autodiff carries)
    attn_block_q: int = 512        # blocked-attention tile sizes
    attn_block_kv: int = 1024
    use_flash_kernel: bool = False  # Pallas flash attention (TPU)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_causal_lm(self) -> bool:
        return self.family in ("dense", "moe", "ssm", "hybrid", "vlm")

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND math."""
        d, hd = self.d_model, self.resolved_head_dim
        qo = self.n_heads * hd * d * 2
        kv = self.n_kv_heads * hd * d * 2
        if self.mla is not None:
            m = self.mla
            q_dim = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            attn = (d * q_dim                           # q (full-rank)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = qo + kv
        if self.mlp_type == "swiglu":
            def ffn(h):
                return 3 * d * h
        else:
            def ffn(h):
                return 2 * d * h
        blocks = 0
        for layer in range(self.n_layers):
            blocks += attn if self._layer_has_attn(layer) else 0
            if self.ssm is not None and self._layer_is_ssm(layer):
                s = self.ssm
                d_in = s.expand * d
                n_h = d_in // s.head_dim
                blocks += (d * (2 * d_in + 2 * s.n_groups * s.state_size + n_h)
                           + d_in * d + d_in * s.conv_kernel)
            elif self.moe is not None and layer >= self.moe.n_dense_layers:
                m = self.moe
                blocks += ((m.n_experts + m.n_shared) * ffn(m.d_expert)
                           + d * m.n_experts)
            elif self._layer_has_attn(layer) or self.ssm is None:
                blocks += ffn(self.d_ff)
        if self.n_encoder_layers:
            blocks += self.n_encoder_layers * (qo + kv + ffn(self.d_ff) + qo)
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return embed + blocks

    def active_param_count(self) -> int:
        """Active parameters per token (=param_count for non-MoE)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        all_experts = (self.n_layers - m.n_dense_layers) * m.n_experts * \
            (3 if self.mlp_type == "swiglu" else 2) * self.d_model * m.d_expert
        active_experts = (self.n_layers - m.n_dense_layers) * m.top_k * \
            (3 if self.mlp_type == "swiglu" else 2) * self.d_model * m.d_expert
        return full - all_experts + active_experts

    def _layer_has_attn(self, layer: int) -> bool:
        if self.family in ("ssm",):
            return False
        if self.family == "hybrid":
            return self.hybrid_attn_period > 0 and \
                (layer + 1) % self.hybrid_attn_period == 0
        return True

    def _layer_is_ssm(self, layer: int) -> bool:
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True                      # zamba2: every layer is mamba2;
        return False                         # attention is an EXTRA shared block

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        shrink = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            max_seq_len=256,
            attn_block_q=32,
            attn_block_kv=32,
            dtype="float32",
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            encoder_seq_len=16 if self.encoder_seq_len else 0,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            hybrid_attn_period=2 if self.hybrid_attn_period else 0,
        )
        if self.moe is not None:
            shrink["moe"] = MoEConfig(
                n_experts=4, top_k=2, d_expert=32,
                n_shared=min(self.moe.n_shared, 1),
                n_dense_layers=min(self.moe.n_dense_layers, 1))
        if self.mla is not None:
            shrink["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                                      qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm is not None:
            shrink["ssm"] = SSMConfig(state_size=16, head_dim=16, n_groups=1,
                                      conv_kernel=4, expand=2, chunk_size=32)
        shrink.update(overrides)
        return dataclasses.replace(self, **shrink)
