"""Activation-sharding hints for GSPMD.

Pure model code stays mesh-agnostic: the launcher installs the logical->mesh
mapping here (a module-level context), and the model inserts
``with_sharding_constraint`` hints at the propagation-critical points
(residual stream, attention heads, logits). Without these, GSPMD happily
picks contraction-dim partitionings that replicate the batch and all-reduce
full activations (observed: f32[256,4096,*] all-reduces, ~6 GB/layer).

When no hints are installed (CPU unit tests, single-device), every helper is
the identity.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardingHints:
    batch_axes: tuple            # mesh axes carrying the global batch
    model_axis: str | None       # tensor-parallel axis name
    model_size: int              # size of the model axis


_HINTS: ShardingHints | None = None


def install(hints: ShardingHints | None):
    global _HINTS
    _HINTS = hints


@contextlib.contextmanager
def hints_ctx(hints: ShardingHints | None):
    global _HINTS
    prev = _HINTS
    _HINTS = hints
    try:
        yield
    finally:
        _HINTS = prev


def current() -> ShardingHints | None:
    return _HINTS


def _wsc(x, spec):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x       # no mesh context (plain CPU tests)


def shard_batch(x, *, extra_dims: int | None = None):
    """Constrain dim 0 to the batch axes, rest unsharded.

    x: (B, ...). Used on the residual stream and batch-major intermediates.
    """
    h = _HINTS
    if h is None:
        return x
    return _wsc(x, P(h.batch_axes, *([None] * (x.ndim - 1))))


def shard_heads(x):
    """x: (B, S, H, hd) — batch over batch axes, heads over model when the
    head count divides the model axis; otherwise heads replicated."""
    h = _HINTS
    if h is None:
        return x
    n_heads = x.shape[2]
    head_spec = h.model_axis if (
        h.model_axis and n_heads % h.model_size == 0) else None
    return _wsc(x, P(h.batch_axes, None, head_spec, None))


def shard_scores(s):
    """s: (B, H, q, k) attention scores — heads over model when divisible."""
    h = _HINTS
    if h is None:
        return s
    head_spec = h.model_axis if (
        h.model_axis and s.shape[1] % h.model_size == 0) else None
    return _wsc(s, P(h.batch_axes, head_spec, None, None))


def shard_ffn(x):
    """x: (B, S, F) — F over model when divisible (MLP hidden)."""
    h = _HINTS
    if h is None:
        return x
    f_spec = h.model_axis if (
        h.model_axis and x.shape[-1] % h.model_size == 0) else None
    return _wsc(x, P(h.batch_axes, None, f_spec))


def shard_logits(x):
    """x: (..., V) — vocab over model when divisible."""
    h = _HINTS
    if h is None:
        return x
    v_spec = h.model_axis if (
        h.model_axis and x.shape[-1] % h.model_size == 0) else None
    return _wsc(x, P(h.batch_axes, *([None] * (x.ndim - 2)), v_spec))


def shard_experts(x):
    """x: (E, C, D) expert buffers — E over model (expert parallelism)."""
    h = _HINTS
    if h is None:
        return x
    e_spec = h.model_axis if (
        h.model_axis and x.shape[0] % h.model_size == 0) else None
    return _wsc(x, P(e_spec, *([None] * (x.ndim - 1))))


def from_mesh(mesh, *, inside_pod_vmap: bool = False) -> ShardingHints:
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if inside_pod_vmap:
        batch = tuple(a for a in batch if a != "pod")
    model_axis = "model" if "model" in mesh.axis_names else None
    return ShardingHints(batch_axes=batch, model_axis=model_axis,
                         model_size=mesh.shape.get("model", 1))
