"""Encoder-decoder (Whisper backbone).

The audio frontend (mel + conv downsampling) is a STUB per the assignment:
``frames`` arrive as precomputed post-conv frame embeddings
(B, encoder_seq_len, d_model). Encoder uses sinusoidal positions and full
self-attention; decoder uses learned positions, causal self-attention and
cross-attention to the encoder output. LayerNorm + GELU, tied unembedding —
Whisper conventions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (blocked_attention, cached_attention,
                                    attention_init, cross_kv, dense,
                                    init_kv_cache)
from repro.models.layers import (apply_norm, cross_entropy, embed,
                                 embedding_init, mlp, mlp_init, norm_init,
                                 sinusoidal_positions, unembed)


def _norm(cfg):
    return norm_init(cfg.d_model, kind=cfg.norm_type)


def _an(cfg, p, x):
    return apply_norm(p, x, kind=cfg.norm_type)


def _self_attn(params, cfg, x, *, causal):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense(params["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(params["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    out = blocked_attention(q, k, v, causal=causal,
                            schedule="triangle" if causal else "full",
                            block_q=cfg.attn_block_q,
                            block_kv=cfg.attn_block_kv)
    return dense(params["wo"], out.reshape(b, s, -1))


def _cross_attn(params, cfg, x, kv):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(params["wq"], x).reshape(b, s, cfg.n_heads, hd)
    out = blocked_attention(q, kv[0], kv[1], causal=False, schedule="full",
                            block_q=cfg.attn_block_q,
                            block_kv=cfg.attn_block_kv)
    return dense(params["wo"], out.reshape(b, s, -1))


def enc_block_init(rng, cfg):
    k1, k2 = jax.random.split(rng)
    return {"norm1": _norm(cfg), "attn": attention_init(k1, cfg),
            "norm2": _norm(cfg),
            "ffn": mlp_init(k2, cfg.d_model, cfg.d_ff, kind=cfg.mlp_type)}


def dec_block_init(rng, cfg):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"norm1": _norm(cfg), "self_attn": attention_init(k1, cfg),
            "norm_x": _norm(cfg), "cross_attn": attention_init(k2, cfg),
            "norm2": _norm(cfg),
            "ffn": mlp_init(k3, cfg.d_model, cfg.d_ff, kind=cfg.mlp_type)}


def encdec_init(cfg, rng):
    ke, kd, kt, kp = jax.random.split(rng, 4)
    return {
        "enc_blocks": jax.vmap(lambda k: enc_block_init(k, cfg))(
            jax.random.split(ke, cfg.n_encoder_layers)),
        "enc_norm": _norm(cfg),
        "embed": embedding_init(kt, cfg.vocab_size, cfg.d_model),
        "pos_embed": jax.random.normal(
            kp, (cfg.max_seq_len, cfg.d_model)) * 0.01,
        "dec_blocks": jax.vmap(lambda k: dec_block_init(k, cfg))(
            jax.random.split(kd, cfg.n_layers)),
        "dec_norm": _norm(cfg),
    }


def encode(params, cfg, frames):
    """frames: (B, S_enc, d) stub embeddings -> encoder states."""
    s = frames.shape[1]
    x = frames + sinusoidal_positions(s, cfg.d_model).astype(frames.dtype)

    def body(h, bp):
        h = h + _self_attn(bp["attn"], cfg, _an(cfg, bp["norm1"], h),
                           causal=False)
        h = h + mlp(bp["ffn"], _an(cfg, bp["norm2"], h), kind=cfg.mlp_type)
        return h, None

    fn = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return _an(cfg, params["enc_norm"], x)


def encdec_forward(params, cfg, frames, tokens):
    """Teacher-forced forward: logits (B, S_dec, V)."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    x = embed(params["embed"], tokens).astype(enc.dtype)
    x = x + params["pos_embed"][:s].astype(x.dtype)

    def body(h, bp):
        h = h + _self_attn(bp["self_attn"], cfg, _an(cfg, bp["norm1"], h),
                           causal=True)
        kv = cross_kv(bp["cross_attn"], cfg, enc)
        h = h + _cross_attn(bp["cross_attn"], cfg, _an(cfg, bp["norm_x"], h),
                            kv)
        h = h + mlp(bp["ffn"], _an(cfg, bp["norm2"], h), kind=cfg.mlp_type)
        return h, None

    fn = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
    x = _an(cfg, params["dec_norm"], x)
    return unembed(params["embed"], x)


def encdec_loss(params, cfg, batch):
    tokens = batch["tokens"]
    logits = encdec_forward(params, cfg, batch["frames"], tokens[:, :-1])
    return cross_entropy(logits, tokens[:, 1:], batch.get("loss_mask"))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def encdec_decode_init(params, cfg, frames, max_len: int,
                       dtype=jnp.bfloat16):
    """Run the encoder once; precompute per-layer cross K/V; empty self cache."""
    enc = encode(params, cfg, frames)
    batch = frames.shape[0]

    def layer_kv(bp):
        k, v = cross_kv(bp["cross_attn"], cfg, enc)
        return {"k": k.astype(dtype), "v": v.astype(dtype)}

    cross = jax.vmap(lambda bp: layer_kv(bp))(params["dec_blocks"])
    self_cache = jax.vmap(
        lambda _: init_kv_cache(cfg, batch, max_len, dtype)
    )(jnp.arange(cfg.n_layers))
    return {"cross": cross, "self": self_cache,
            "position": jnp.zeros((batch,), jnp.int32)}


def encdec_decode_step(params, cfg, cache, tokens):
    """tokens: (B,) -> (logits (B, V), new cache)."""
    b = tokens.shape[0]
    hd = cfg.resolved_head_dim
    pos = cache["position"]
    x = embed(params["embed"], tokens[:, None])
    x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None].astype(x.dtype)

    def body(x, xs):
        bp, sc, cc = xs
        h = _an(cfg, bp["norm1"], x)
        q = dense(bp["self_attn"]["wq"], h).reshape(b, 1, cfg.n_heads, hd)
        k = dense(bp["self_attn"]["wk"], h).reshape(b, 1, cfg.n_kv_heads, hd)
        v = dense(bp["self_attn"]["wv"], h).reshape(b, 1, cfg.n_kv_heads, hd)
        idx = sc["length"][:, None, None, None]
        onehot = (jnp.arange(sc["k"].shape[1])[None, :, None, None] == idx)
        kc = jnp.where(onehot, k.astype(sc["k"].dtype), sc["k"])
        vc = jnp.where(onehot, v.astype(sc["v"].dtype), sc["v"])
        out = cached_attention(q, kc, vc, sc["length"] + 1)
        x = x + dense(bp["self_attn"]["wo"], out.reshape(b, 1, -1))
        new_sc = {"k": kc, "v": vc, "length": sc["length"] + 1}

        h = _an(cfg, bp["norm_x"], x)
        q = dense(bp["cross_attn"]["wq"], h).reshape(b, 1, cfg.n_heads, hd)
        enc_len = jnp.full((b,), cc["k"].shape[1], jnp.int32)
        out = cached_attention(q, cc["k"], cc["v"], enc_len)
        x = x + dense(bp["cross_attn"]["wo"], out.reshape(b, 1, -1))

        x = x + mlp(bp["ffn"], _an(cfg, bp["norm2"], x), kind=cfg.mlp_type)
        return x, new_sc

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross"]))
    x = _an(cfg, params["dec_norm"], x)
    logits = unembed(params["embed"], x)
    return logits[:, 0], {"cross": cache["cross"], "self": new_self,
                          "position": pos + 1}
