"""Hierarchical weighted aggregation — Pallas TPU kernel.

The edge/cloud model average (paper eqs. 8 / 14) over C stacked client
updates is memory-bound: a naive HLO chain reads the (C, P) stack several
times (multiply, add-reduce, divide). The kernel fuses normalize + weight +
reduce into a single pass: parameter dimension tiled across the grid, the
full client axis resident per tile, f32 accumulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(u_ref, w_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)                      # (C,)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)
    u = u_ref[...].astype(jnp.float32)                      # (C, bp)
    o_ref[...] = jnp.dot(w, u,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def hier_aggregate(updates, weights, *, block_p: int = 65_536,
                   interpret: bool = False):
    """updates: (C, P); weights: (C,) -> weighted average (P,)."""
    c, p = updates.shape
    block_p = min(block_p, p)
    pad = (-p) % block_p
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    n_blocks = updates.shape[1] // block_p

    out = pl.pallas_call(
        _agg_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((c, block_p), lambda i: (0, i)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((updates.shape[1],), updates.dtype),
        interpret=interpret,
    )(updates, weights)
    return out[:p] if pad else out
