"""Fused RMSNorm — Pallas TPU kernel.

One pass over HBM instead of the separate square/mean/rsqrt/mul HLO chain:
rows are tiled (block_rows x d) into VMEM, statistics in f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                      # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)[None, :]).astype(
        o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
