"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        softmax_scale: float | None = None):
    """Naive attention. q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd)."""
    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    qr = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd).astype(q.dtype)


def rmsnorm_ref(x, scale, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def hier_aggregate_ref(updates, weights):
    """Weighted average over the leading client axis — eq. (8)/(14).

    updates: (C, P); weights: (C,). Returns (P,) in updates.dtype.
    """
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)
    return jnp.einsum("c,cp->p", w,
                      updates.astype(jnp.float32)).astype(updates.dtype)


def golden_section_ref(a, b, d, e, w, f_min, f_max, mask, *,
                       n_golden: int = 48, n_inner: int = 12,
                       n_bracket: int = 60):
    """Batched KKT-path RA solve — the plain-jnp mirror of the fused
    golden-section kernel (and of ``solve_fixed_point`` vmapped over
    groups). Constants (G, R), ``w`` (G,); returns (f, beta, cost, deadline)
    with cost/deadline shaped (G,).
    """
    golden = 0.6180339887498949
    eps = 1e-12
    mask = jnp.asarray(mask, bool)
    w = jnp.asarray(w)[:, None]

    def beta_norm(score):
        score = jnp.where(mask, score, 0.0)
        tot = jnp.maximum(jnp.sum(score, axis=-1, keepdims=True), eps)
        return jnp.where(mask, score / tot, 0.0)

    def beta_of_f(f):
        tau = 2.0 * b * f ** 3 / jnp.maximum(e, eps)
        return beta_norm(jnp.cbrt(jnp.maximum(a + tau * d, eps)))

    def safe(beta):
        return jnp.where(mask, jnp.maximum(beta, eps), 1.0)

    def bound_hi(fx):
        lo = jnp.max(jnp.where(mask, e / fx + d, 0.0), -1, keepdims=True)
        hi = lo + jnp.sum(jnp.where(mask, d, 0.0), -1,
                          keepdims=True) * 1e4 + 1.0

        def body(_, lohi):
            lo_, hi_ = lohi
            mid = 0.5 * (lo_ + hi_)
            slack = mid - e / fx
            bb = jnp.where(mask, d / jnp.maximum(slack, eps), 0.0)
            bb = jnp.where(mask & (slack <= 0), 1e6, bb)
            ok = jnp.sum(bb, -1, keepdims=True) <= 1.0
            return (jnp.where(ok, lo_, mid), jnp.where(ok, mid, hi_))

        return jax.lax.fori_loop(0, n_bracket, body, (lo, hi))[1]

    t_lo = bound_hi(f_max) * (1.0 + 1e-6)
    t_hi = jnp.maximum(bound_hi(f_min) * 1.5, t_lo * 4.0) + 1.0

    def fb_of_t(t):
        def body(_, f):
            slack = t - d / safe(beta_of_f(f))
            f_new = jnp.where(slack > 0, e / jnp.maximum(slack, eps), f_max)
            return jnp.clip(f_new, f_min, f_max)

        f = jax.lax.fori_loop(0, n_inner, body, jnp.sqrt(f_min * f_max))
        return f, beta_of_f(f)

    def objective(f, safe_beta):
        per_sum = a / safe_beta + b * jnp.square(f)
        per_max = d / safe_beta + e / f
        return (jnp.sum(jnp.where(mask, per_sum, 0.0), -1, keepdims=True)
                + w * jnp.max(jnp.where(mask, per_max, 0.0), -1,
                              keepdims=True))

    def cost_of_t(t):
        f, beta = fb_of_t(t)
        return objective(f, safe(beta))

    m1 = t_hi - golden * (t_hi - t_lo)
    m2 = t_lo + golden * (t_hi - t_lo)
    c1, c2 = cost_of_t(m1), cost_of_t(m2)

    def gbody(_, st):
        lo, hi, m1, m2, c1, c2 = st
        go_right = c1 > c2
        lo = jnp.where(go_right, m1, lo)
        hi = jnp.where(go_right, hi, m2)
        m1n = hi - golden * (hi - lo)
        m2n = lo + golden * (hi - lo)
        point = jnp.where(go_right, m2n, m1n)
        cp = cost_of_t(point)
        return (lo, hi,
                jnp.where(go_right, m2, point), jnp.where(go_right, point, m1),
                jnp.where(go_right, c2, cp), jnp.where(go_right, cp, c1))

    lo, hi, *_ = jax.lax.fori_loop(0, n_golden, gbody,
                                   (t_lo, t_hi, m1, m2, c1, c2))
    f, beta = fb_of_t(0.5 * (lo + hi))

    any_active = jnp.any(mask, -1, keepdims=True)
    f = jnp.where(mask, jnp.clip(f, f_min, f_max), f_min)
    beta = beta_norm(jnp.maximum(beta, eps))
    sb = safe(beta)
    cost = jnp.where(any_active, objective(f, sb), 0.0)
    deadline = jnp.max(jnp.where(mask, d / sb + e / f, 0.0), -1,
                       keepdims=True)
    return f, beta, cost[:, 0], deadline[:, 0]


def ssd_state_scan_ref(states, decay, initial_state=None):
    """Inter-chunk SSD recurrence.

    states: (NC, B, H, N, P) per-chunk accumulated states;
    decay:  (NC, B, H) per-chunk total decay.
    Returns (entering (NC, B, H, N, P), final (B, H, N, P)) where
    ``entering[c]`` is the carried state at the START of chunk c.
    """
    nc, b, h, n, p = states.shape
    init = (jnp.zeros((b, h, n, p), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def body(carry, xs):
        st, dec = xs
        new = carry * dec.astype(jnp.float32)[..., None, None] + \
            st.astype(jnp.float32)
        return new, carry

    final, entering = jax.lax.scan(body, init,
                                   (states.astype(jnp.float32),
                                    decay.astype(jnp.float32)))
    return entering.astype(states.dtype), final.astype(states.dtype)
