"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        softmax_scale: float | None = None):
    """Naive attention. q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd)."""
    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    qr = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd).astype(q.dtype)


def rmsnorm_ref(x, scale, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def hier_aggregate_ref(updates, weights):
    """Weighted average over the leading client axis — eq. (8)/(14).

    updates: (C, P); weights: (C,). Returns (P,) in updates.dtype.
    """
    w = weights.astype(jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-30)
    return jnp.einsum("c,cp->p", w,
                      updates.astype(jnp.float32)).astype(updates.dtype)


def ssd_state_scan_ref(states, decay, initial_state=None):
    """Inter-chunk SSD recurrence.

    states: (NC, B, H, N, P) per-chunk accumulated states;
    decay:  (NC, B, H) per-chunk total decay.
    Returns (entering (NC, B, H, N, P), final (B, H, N, P)) where
    ``entering[c]`` is the carried state at the START of chunk c.
    """
    nc, b, h, n, p = states.shape
    init = (jnp.zeros((b, h, n, p), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))

    def body(carry, xs):
        st, dec = xs
        new = carry * dec.astype(jnp.float32)[..., None, None] + \
            st.astype(jnp.float32)
        return new, carry

    final, entering = jax.lax.scan(body, init,
                                   (states.astype(jnp.float32),
                                    decay.astype(jnp.float32)))
    return entering.astype(states.dtype), final.astype(states.dtype)
