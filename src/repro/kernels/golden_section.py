"""Batched golden-section fixed-point RA solver — Pallas TPU kernel.

Fuses the whole :func:`repro.core.resource_allocation.solve_fixed_point`
iteration stack — the 2x``n_bracket`` feasible-deadline bisection, the
``n_golden`` golden-section probes each paying an ``n_inner`` beta<->f KKT
fixed point, and the final clip/normalize — into ONE kernel pass over a
block of candidate groups. The XLA path lowers the same math to hundreds of
tiny sequential HLO ops *per group*; here every probe is a VMEM-resident
vector op over the (block_g, R) group block, so the sequential depth is paid
once per block instead of once per group and nothing round-trips HBM between
iterations.

Group constants follow :class:`repro.core.cost_model.RAConstants` leaf
layout batched over groups: ``a, b, d, e, f_min, f_max, mask`` are
``(G, R)`` and ``w`` is ``(G,)`` (one scalar weight per group). The math
mirrors ``solve_fixed_point`` op-for-op, so interpret mode reproduces the
XLA solver to float32 rounding (the parity tests pin the tolerance).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

_GOLDEN = 0.6180339887498949
_EPS = 1e-12


def _golden_section_kernel(a_ref, b_ref, d_ref, e_ref, w_ref, fmin_ref,
                           fmax_ref, mask_ref, f_ref, beta_ref, cost_ref,
                           dl_ref, *, n_golden: int, n_inner: int,
                           n_bracket: int):
    a = a_ref[...]
    b = b_ref[...]
    d = d_ref[...]
    e = e_ref[...]
    w = w_ref[...]                     # (g, 1)
    f_min = fmin_ref[...]
    f_max = fmax_ref[...]
    mask = mask_ref[...]               # (g, r) bool

    def beta_norm(score):
        score = jnp.where(mask, score, 0.0)
        tot = jnp.maximum(jnp.sum(score, axis=-1, keepdims=True), _EPS)
        return jnp.where(mask, score / tot, 0.0)

    def beta_of_f(f):
        tau = 2.0 * b * f ** 3 / jnp.maximum(e, _EPS)
        return beta_norm(jnp.cbrt(jnp.maximum(a + tau * d, _EPS)))

    def safe(beta):
        return jnp.where(mask, jnp.maximum(beta, _EPS), 1.0)

    # ---- feasible deadline bracket: bisect sum_n beta_min(t) <= 1 with
    # every device at f_max (lower end) and at f_min (upper end); both
    # searches run stacked so the depth is n_bracket, not 2x ----
    def bound_hi(fx):
        lo = jnp.max(jnp.where(mask, e / fx + d, 0.0), axis=-1, keepdims=True)
        hi = lo + jnp.sum(jnp.where(mask, d, 0.0), axis=-1,
                          keepdims=True) * 1e4 + 1.0

        def body(_, lohi):
            lo_, hi_ = lohi
            mid = 0.5 * (lo_ + hi_)
            slack = mid - e / fx
            bb = jnp.where(mask, d / jnp.maximum(slack, _EPS), 0.0)
            bb = jnp.where(mask & (slack <= 0), 1e6, bb)
            ok = jnp.sum(bb, axis=-1, keepdims=True) <= 1.0
            return (jnp.where(ok, lo_, mid), jnp.where(ok, mid, hi_))

        _, hi_ = lax.fori_loop(0, n_bracket, body, (lo, hi))
        return hi_

    t_lo = bound_hi(f_max) * (1.0 + 1e-6)                        # (g, 1)
    t_hi = jnp.maximum(bound_hi(f_min) * 1.5, t_lo * 4.0) + 1.0

    def fb_of_t(t):
        def body(_, f):
            slack = t - d / safe(beta_of_f(f))
            f_new = jnp.where(slack > 0, e / jnp.maximum(slack, _EPS), f_max)
            return jnp.clip(f_new, f_min, f_max)

        f = lax.fori_loop(0, n_inner, body, jnp.sqrt(f_min * f_max))
        return f, beta_of_f(f)

    def objective(f, safe_beta):
        per_sum = a / safe_beta + b * jnp.square(f)
        per_max = d / safe_beta + e / f
        return (jnp.sum(jnp.where(mask, per_sum, 0.0), -1, keepdims=True)
                + w * jnp.max(jnp.where(mask, per_max, 0.0), -1,
                              keepdims=True))

    def cost_of_t(t):
        f, beta = fb_of_t(t)
        return objective(f, safe(beta))

    # ---- golden-section over t, single-eval recurrence (G^2 = 1 - G) ----
    m1 = t_hi - _GOLDEN * (t_hi - t_lo)
    m2 = t_lo + _GOLDEN * (t_hi - t_lo)
    c1, c2 = cost_of_t(m1), cost_of_t(m2)

    def gbody(_, st):
        lo, hi, m1, m2, c1, c2 = st
        go_right = c1 > c2
        lo = jnp.where(go_right, m1, lo)
        hi = jnp.where(go_right, hi, m2)
        m1n = hi - _GOLDEN * (hi - lo)
        m2n = lo + _GOLDEN * (hi - lo)
        point = jnp.where(go_right, m2n, m1n)
        cp = cost_of_t(point)
        m1_new = jnp.where(go_right, m2, point)
        c1_new = jnp.where(go_right, c2, cp)
        m2_new = jnp.where(go_right, point, m1)
        c2_new = jnp.where(go_right, cp, c1)
        return lo, hi, m1_new, m2_new, c1_new, c2_new

    lo, hi, *_ = lax.fori_loop(0, n_golden, gbody,
                               (t_lo, t_hi, m1, m2, c1, c2))
    f, beta = fb_of_t(0.5 * (lo + hi))

    # ---- finalize (clip/renormalize; empty groups cost 0) ----
    any_active = jnp.any(mask, axis=-1, keepdims=True)
    f = jnp.where(mask, jnp.clip(f, f_min, f_max), f_min)
    beta = beta_norm(jnp.maximum(beta, _EPS))
    sb = safe(beta)
    f_ref[...] = f
    beta_ref[...] = beta
    cost_ref[...] = jnp.where(any_active, objective(f, sb), 0.0)
    dl_ref[...] = jnp.max(jnp.where(mask, d / sb + e / f, 0.0), -1,
                          keepdims=True)


def golden_section_solve(a, b, d, e, w, f_min, f_max, mask, *,
                         n_golden: int = 48, n_inner: int = 12,
                         n_bracket: int = 60, block_g: int = 256,
                         interpret: bool = False):
    """Solve G groups of problem (18) at once along the KKT deadline path.

    ``a, b, d, e, f_min, f_max, mask``: (G, R); ``w``: (G,). Returns
    ``(f (G, R), beta (G, R), cost (G,), deadline (G,))``.
    """
    g, r = a.shape
    block_g = max(min(block_g, g), 1)
    pad = (-g) % block_g

    def pad2(x, value=0.0):
        x = jnp.asarray(x)
        if not pad:
            return x
        return jnp.pad(x, ((0, pad), (0, 0)), constant_values=value)

    # padded rows get benign all-masked-out groups: unit constants keep the
    # bracket/fixed-point arithmetic finite, mask=False keeps them inert
    a2, b2, d2 = pad2(a, 1.0), pad2(b, 1.0), pad2(d, 1.0)
    e2, fmin2, fmax2 = pad2(e, 1.0), pad2(f_min, 1.0), pad2(f_max, 1.0)
    mask2 = pad2(mask.astype(bool), False)
    w2 = jnp.asarray(w, a2.dtype).reshape(g, 1)
    if pad:
        w2 = jnp.pad(w2, ((0, pad), (0, 0)))
    g2 = g + pad
    n_blocks = g2 // block_g

    row_spec = pl.BlockSpec((block_g, r), lambda i: (i, 0))
    one_spec = pl.BlockSpec((block_g, 1), lambda i: (i, 0))
    f, beta, cost, dl = pl.pallas_call(
        functools.partial(_golden_section_kernel, n_golden=n_golden,
                          n_inner=n_inner, n_bracket=n_bracket),
        grid=(n_blocks,),
        in_specs=[row_spec] * 4 + [one_spec] + [row_spec] * 3,
        out_specs=[row_spec, row_spec, one_spec, one_spec],
        out_shape=[
            jax.ShapeDtypeStruct((g2, r), a2.dtype),
            jax.ShapeDtypeStruct((g2, r), a2.dtype),
            jax.ShapeDtypeStruct((g2, 1), a2.dtype),
            jax.ShapeDtypeStruct((g2, 1), a2.dtype),
        ],
        interpret=interpret,
    )(a2, b2, d2, e2, w2, fmin2, fmax2, mask2)
    return (f[:g], beta[:g], cost[:g, 0], dl[:g, 0])
