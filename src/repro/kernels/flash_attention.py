"""Flash attention — Pallas TPU kernel (forward) with online softmax.

TPU adaptation (not a CUDA port): the kernel exploits the sequential
execution of the trailing grid axis on TPU — the KV axis is the innermost
grid dimension and the running (acc, m, l) statistics live in VMEM scratch
that persists across those sequential steps. Tiles are MXU-aligned
(block_q x head_dim and block_kv x head_dim with head_dim a multiple of
128 on real configs); softmax statistics are f32 regardless of input dtype.

Layout inside the kernel: (B, H, S, hd). GQA is handled by the k/v
BlockSpec index maps (q head h reads kv head h // group).

Causal handling: fully-masked kv tiles are skipped via ``pl.when`` (the
triangle schedule); the diagonal tile applies the position mask.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                causal: bool, block_q: int, block_kv: int, n_kv: int,
                scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    kv_start = ik * block_kv
    # last kv tile this q tile can see (inclusive), for the final write
    if causal:
        last_ik = jnp.minimum((q_start + block_q - 1) // block_kv, n_kv - 1)
        visible = kv_start <= q_start + block_q - 1
    else:
        last_ik = n_kv - 1
        visible = True

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kv_pos = kv_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(kv_pos <= q_pos, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == last_ik)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, block_q: int = 512,
                        block_kv: int = 512,
                        softmax_scale: float | None = None,
                        interpret: bool = False):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd)."""
    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    nq, nk = sq // block_q, skv // block_kv

    # kernel layout (B, H, S, hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_fwd_kernel, causal=causal, block_q=block_q,
                               block_kv=block_kv, n_kv=nk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h, iq, ik: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h, iq, ik: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
