"""Public jit'd wrappers around the Pallas kernels.

On non-TPU backends (this container is CPU) the kernels run in
``interpret=True`` mode — the kernel body executes in Python/XLA for
correctness validation; on TPU they compile to Mosaic. ``flash_attention``
is differentiable via custom_vjp: the forward is the Pallas kernel, the
backward recomputes through the reference formulation (flash-style
recompute — no (Sq, Skv) residuals are stored).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.golden_section import \
    golden_section_solve as _golden_section_solve
from repro.kernels.hier_aggregate import hier_aggregate as _hier_aggregate
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.ssd_scan import ssd_state_scan as _ssd_state_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 512,
                    block_kv: int = 512):
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv, interpret=not _on_tpu())


def _fa_fwd(q, k, v, causal, block_q, block_kv):
    out = flash_attention(q, k, v, causal, block_q, block_kv)
    return out, (q, k, v)


def _fa_bwd(causal, block_q, block_kv, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(q_, k_, v_,
                                                    causal=causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256):
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=not _on_tpu())


def hier_aggregate(updates, weights, *, block_p: int = 65_536):
    return _hier_aggregate(updates, weights, block_p=block_p,
                           interpret=not _on_tpu())


def hier_aggregate_tree(trees: list, weights):
    """Weighted-average a list of pytrees through the fused kernel."""
    flat = [jnp.concatenate([leaf.reshape(-1) for leaf in jax.tree.leaves(t)])
            for t in trees]
    stacked = jnp.stack(flat)
    merged = hier_aggregate(stacked, jnp.asarray(weights))
    # unflatten back into the first tree's structure
    leaves, treedef = jax.tree.flatten(trees[0])
    out, off = [], 0
    for leaf in leaves:
        out.append(merged[off:off + leaf.size].reshape(leaf.shape)
                   .astype(leaf.dtype))
        off += leaf.size
    return jax.tree.unflatten(treedef, out)


def golden_section_solve(a, b, d, e, w, f_min, f_max, mask, *,
                         n_golden: int = 48, n_inner: int = 12,
                         n_bracket: int = 60, block_g: int = 256):
    """Batched fused golden-section RA solve; see
    :mod:`repro.kernels.golden_section` for shapes."""
    return _golden_section_solve(a, b, d, e, w, f_min, f_max, mask,
                                 n_golden=n_golden, n_inner=n_inner,
                                 n_bracket=n_bracket, block_g=block_g,
                                 interpret=not _on_tpu())


def ssd_state_scan(states, decay, initial_state=None):
    return _ssd_state_scan(states, decay, initial_state,
                           interpret=not _on_tpu())
