"""Mamba2 SSD inter-chunk state recurrence — Pallas TPU kernel.

The chunked SSD algorithm reduces the sequential work to a short recurrence
over per-chunk states:  S_{c+1} = decay_c * S_c + states_c.  The kernel runs
one (batch, head) tile per grid cell with the full chunk axis walked by a
``fori_loop`` whose (N, P) carry stays in VMEM — no HBM round-trip between
chunks (the pure-JAX ``lax.scan`` reads/writes the carry through HBM each
step).

Emits the state ENTERING each chunk (what the intra-chunk pass consumes)
plus the final state (the decode/serving handoff).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(states_ref, decay_ref, init_ref, entering_ref, final_ref):
    nc = states_ref.shape[0]
    n, p = states_ref.shape[3], states_ref.shape[4]

    def body(c, carry):
        entering_ref[c, 0, 0] = carry.astype(entering_ref.dtype)
        dec = decay_ref[c, 0, 0]
        new = carry * dec + states_ref[c, 0, 0].astype(jnp.float32)
        return new

    carry0 = init_ref[0, 0].astype(jnp.float32)
    final = jax.lax.fori_loop(0, nc, body, carry0)
    final_ref[0, 0] = final.astype(final_ref.dtype)


def ssd_state_scan(states, decay, initial_state=None, *,
                   interpret: bool = False):
    """states: (NC, B, H, N, P); decay: (NC, B, H).

    Returns (entering (NC, B, H, N, P), final (B, H, N, P)).
    """
    nc, b, h, n, p = states.shape
    if initial_state is None:
        initial_state = jnp.zeros((b, h, n, p), states.dtype)
    decay_b = jnp.broadcast_to(decay[..., None, None], states.shape)

    entering, final = pl.pallas_call(
        _scan_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((nc, 1, 1, n, p), lambda b_, h_: (0, b_, h_, 0, 0)),
            pl.BlockSpec((nc, 1, 1, n, p), lambda b_, h_: (0, b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nc, 1, 1, n, p), lambda b_, h_: (0, b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(states.shape, states.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), states.dtype),
        ],
        interpret=interpret,
    )(states, decay_b, initial_state)
    return entering, final
