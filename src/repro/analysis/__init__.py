"""hfellint: repo-specific static analysis + the recompilation sentinel.

Static side (stdlib-only, no jax import):
  * :mod:`repro.analysis.rules`    — the HFEL001-006 AST rules
  * :mod:`repro.analysis.engine`   — file walking, pragma suppression
  * :mod:`repro.analysis.baseline` — fingerprint baseline diffing

Dynamic side (imports jax, keep it out of the lint fast path):
  * :mod:`repro.analysis.recompile` — ``CompileLog``, the jit-compile-event
    capture behind the tier-1 recompilation-sentinel test
"""

from repro.analysis.baseline import (baseline_counts, diff_against_baseline,
                                     load_baseline, save_baseline)
from repro.analysis.engine import Finding, lint_paths, lint_source

__all__ = ["Finding", "lint_paths", "lint_source", "load_baseline",
           "save_baseline", "baseline_counts", "diff_against_baseline"]
