"""The HFEL lint rules: repo-specific determinism and jit-hygiene checks.

Every headline claim in this repo is a bit-identical parity contract (warm
vs cold re-solves, sharded vs single-device sweeps, pallas vs xla pricing),
and each rule here machine-checks one way those contracts silently rot:

HFEL001  unseeded ``np.random.*`` / ``default_rng()`` — module-level numpy
         RNG state breaks run-to-run determinism.
HFEL002  ``time.time()`` — non-monotonic under NTP; interval timing must use
         ``time.perf_counter()`` (wall-clock uses get a pragma).
HFEL003  host syncs (``float()``/``bool()``/``int()``/``.item()``/
         ``np.asarray``) on traced values inside jitted scopes — a silent
         device->host round trip, or a tracer error at a rarely-hit shape.
HFEL004  Python ``if``/``while``/``for`` over traced values in jitted scopes
         — trace-time branching bakes one branch into the compiled program.
HFEL005  float64 inside ``src/repro/kernels`` or jitted scopes — the sweep's
         cost arithmetic is float32 by contract; a stray float64 literal
         flips comparison outcomes between backends.
HFEL006  decorator-jitted functions with >= 4 traced array params and no
         ``donate_argnums`` — large resident buffers double peak memory on
         every sweep step.
HFEL007  ``jax.random.split`` / ``fold_in`` inside a ``shard_map``-traced
         scope without an axis-index fold — every shard advances the SAME
         stream, silently correlating what reads like per-shard randomness.
         Fold in ``lax.axis_index(axis)`` to diversify, or pragma the line
         when replication IS the contract (``replicated-key``, e.g. the
         sharded exchange proposal draws identical pairs on every shard by
         design).

Jit-scope detection (documented heuristics, tuned to this repo's idioms):

* decorator forms ``@jax.jit`` and ``@(functools.)partial(jax.jit, ...)``;
* call forms ``jax.jit(f, ...)``, ``jax.jit(jax.vmap(f), ...)``,
  ``shard_map(f, ...)``, ``pl.pallas_call(f, ...)`` — with one level of
  local-variable resolution (``body = partial(impl, ...)`` then
  ``shard_map(body, ...)`` marks ``impl``);
* ``static_argnames`` / ``static_argnums`` and keywords bound by ``partial``
  are static; by repo convention KEYWORD-ONLY params of jitted functions are
  static configuration, not arrays (matches ``_run_device`` /
  ``_run_device_impl`` / every Pallas kernel body);
* nested ``def``s inherit the jitted scope; their positional params are
  traced, their defaulted params are the static loop-capture idiom
  (``lambda x, b=b: ...``).

Taint: traced params, propagated through assignments and ``for`` targets,
de-tainted by shape/dtype-like attribute reads (``.shape``, ``.ndim``,
``.dtype``, ``.size``) and ``len()``. Comprehension generators and direct
``for``-iteration over a param are NOT flagged by HFEL004: the repo iterates
static-length tuples-of-arrays that way (``for bd in buckets``), which is
unrolled at trace time on static structure — only derived array taint fires.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analysis.engine import Finding

# -- dotted-name helpers ------------------------------------------------------

JIT_NAMES = {"jax.jit", "jit"}
PARTIAL_NAMES = {"partial", "functools.partial"}
# transparent wrappers: jit(vmap(f)) etc. resolve through to f
WRAPPER_TAILS = ("jit", "vmap", "pmap", "grad", "value_and_grad",
                 "checkpoint", "remat", "shard_map", "named_call")
DETAINT_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "aval",
                 "sharding", "weak_type", "itemsize"}
DETAINT_CALLS = {"len", "isinstance", "type", "hasattr", "getattr", "id",
                 "repr", "str"}
HOST_SYNC_BUILTINS = {"float", "bool", "int"}
HOST_SYNC_DOTTED = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                    "onp.asarray", "onp.array"}
NP_RANDOM_PREFIXES = ("np.random.", "numpy.random.")
SEEDED_CTOR_TAILS = {"default_rng", "Generator", "RandomState", "PCG64",
                     "Philox", "SFC64", "MT19937"}


def dotted(node: ast.AST) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit(node: ast.AST) -> bool:
    return dotted(node) in JIT_NAMES


def _is_partial(node: ast.AST) -> bool:
    return dotted(node) in PARTIAL_NAMES


def _tail(name: str | None) -> str:
    return name.rsplit(".", 1)[-1] if name else ""


# -- jit-scope analysis -------------------------------------------------------

@dataclass
class JitScope:
    """One function the analysis believes runs traced."""

    node: ast.FunctionDef
    form: str                       # "decorator" | "call" | "pallas"
    static_names: set[str] = field(default_factory=set)
    static_nums: set[int] = field(default_factory=set)
    bound_positional: int = 0       # leading params consumed by partial()
    donates: bool = False
    via_shard_map: bool = False     # traced under a named mesh axis

    def param_split(self) -> tuple[list[str], set[str]]:
        """(traced positional param names, static param names)."""
        a = self.node.args
        positional = [p.arg for p in (a.posonlyargs + a.args)]
        static = set(self.static_names)
        static.update(p.arg for p in a.kwonlyargs)   # repo convention
        for i, name in enumerate(positional):
            if i in self.static_nums or i < self.bound_positional:
                static.add(name)
        if positional and positional[0] in ("self", "cls"):
            static.add(positional[0])
        traced = [p for p in positional if p not in static]
        return traced, static


def _jit_kwargs(call: ast.Call, scope: JitScope) -> None:
    """Fold static_argnames/static_argnums/donate_* keywords into scope."""
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant):
                    if isinstance(c.value, str):
                        scope.static_names.add(c.value)
                    elif isinstance(c.value, int):
                        scope.static_nums.add(c.value)
        elif kw.arg in ("donate_argnums", "donate_argnames"):
            scope.donates = True


def _local_env(tree: ast.AST) -> dict[str, ast.expr]:
    """name -> value for every simple single-target assignment anywhere.

    Flat across scopes — a heuristic, but collisions between a jit-wrapped
    callable alias and an unrelated name are vanishingly rare here."""
    env: dict[str, ast.expr] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            env[node.targets[0].id] = node.value
    return env


def _resolve(expr: ast.expr, defs: dict[str, ast.FunctionDef],
             env: dict[str, ast.expr], scope: JitScope,
             depth: int = 0) -> ast.FunctionDef | None:
    """Follow a callable expression to the FunctionDef it traces, through
    Name aliases, ``partial`` (keywords become static params), and the
    transparent jax wrappers."""
    if depth > 4:
        return None
    if isinstance(expr, ast.Name):
        if expr.id in defs:
            return defs[expr.id]
        if expr.id in env:
            return _resolve(env[expr.id], defs, env, scope, depth + 1)
        return None
    if isinstance(expr, ast.Call):
        name = dotted(expr.func)
        if name in PARTIAL_NAMES and expr.args:
            for kw in expr.keywords:
                if kw.arg:
                    scope.static_names.add(kw.arg)
            scope.bound_positional += len(expr.args) - 1
            return _resolve(expr.args[0], defs, env, scope, depth + 1)
        if _tail(name) in WRAPPER_TAILS and expr.args:
            if _tail(name) == "jit":
                _jit_kwargs(expr, scope)
            return _resolve(expr.args[0], defs, env, scope, depth + 1)
    return None


def find_jit_scopes(tree: ast.AST) -> list[JitScope]:
    defs: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node
    env = _local_env(tree)
    scopes: dict[int, JitScope] = {}

    def add(fn: ast.FunctionDef | None, scope: JitScope) -> None:
        if fn is None:
            return
        scope.node = fn
        prev = scopes.get(id(fn))
        if prev is None:
            scopes[id(fn)] = scope
        else:   # merge: union statics, keep strongest donate signal
            prev.static_names |= scope.static_names
            prev.static_nums |= scope.static_nums
            prev.bound_positional = max(prev.bound_positional,
                                        scope.bound_positional)
            prev.donates = prev.donates or scope.donates
            prev.via_shard_map = prev.via_shard_map or scope.via_shard_map

    # decorator forms
    for fn in defs.values():
        for dec in fn.decorator_list:
            if _is_jit(dec):
                add(fn, JitScope(fn, "decorator"))
            elif isinstance(dec, ast.Call):
                if _is_partial(dec.func) and dec.args and \
                        _is_jit(dec.args[0]):
                    scope = JitScope(fn, "decorator")
                    _jit_kwargs(dec, scope)
                    add(fn, scope)
                elif _is_jit(dec.func):
                    scope = JitScope(fn, "decorator")
                    _jit_kwargs(dec, scope)
                    add(fn, scope)

    # call forms: jax.jit(f, ...), shard_map(f, ...), pl.pallas_call(f, ...)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        tail = _tail(name)
        if tail == "jit" and name in JIT_NAMES and node.args:
            scope = JitScope(None, "call")
            _jit_kwargs(node, scope)
            add(_resolve(node.args[0], defs, env, scope), scope)
        elif tail == "shard_map" and node.args:
            scope = JitScope(None, "call", via_shard_map=True)
            add(_resolve(node.args[0], defs, env, scope), scope)
        elif tail == "pallas_call":
            target = node.args[0] if node.args else next(
                (kw.value for kw in node.keywords if kw.arg == "kernel"),
                None)
            if target is not None:
                scope = JitScope(None, "pallas")
                add(_resolve(target, defs, env, scope), scope)
    return list(scopes.values())


# -- taint --------------------------------------------------------------------

def _expr_tainted(expr: ast.expr, taint: set[str]) -> bool:
    if isinstance(expr, ast.Name):
        return expr.id in taint
    if isinstance(expr, ast.Attribute):
        if expr.attr in DETAINT_ATTRS:
            return False
        return _expr_tainted(expr.value, taint)
    if isinstance(expr, ast.Call):
        name = dotted(expr.func)
        if name in DETAINT_CALLS or _tail(name) in DETAINT_CALLS:
            return False
        if _expr_tainted(expr.func, taint):
            return True
        return any(_expr_tainted(a, taint) for a in expr.args) or \
            any(_expr_tainted(kw.value, taint) for kw in expr.keywords)
    if isinstance(expr, ast.Subscript):
        return _expr_tainted(expr.value, taint)
    if isinstance(expr, ast.Constant):
        return False
    if isinstance(expr, (ast.Lambda, ast.FunctionDef)):
        return False
    return any(_expr_tainted(c, taint) for c in ast.iter_child_nodes(expr)
               if isinstance(c, ast.expr))


def _target_names(target: ast.expr) -> list[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: list[str] = []
        for el in target.elts:
            out.extend(_target_names(el))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []


def _scope_taint(scope: JitScope) -> tuple[set[str], set[str]]:
    """(tainted names, root param names) after propagating through the
    scope's body — one shared namespace for the root and its nested defs."""
    traced, _static = scope.param_split()
    taint = set(traced)
    params = set(traced)
    for inner in ast.walk(scope.node):
        if isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                inner is not scope.node:
            a = inner.args
            n_defaults = len(a.defaults)
            positional = a.posonlyargs + a.args
            for i, p in enumerate(positional):
                # defaulted params are the static capture idiom (b=b)
                if i < len(positional) - n_defaults:
                    taint.add(p.arg)
                    params.add(p.arg)
    # two passes approximate the fixpoint for forward-then-backward flows
    for _ in range(2):
        for node in ast.walk(scope.node):
            if isinstance(node, ast.Assign):
                if _expr_tainted(node.value, taint):
                    for t in node.targets:
                        taint.update(_target_names(t))
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                if node.value is not None and \
                        _expr_tainted(node.value, taint):
                    taint.update(_target_names(node.target))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                if _expr_tainted(node.iter, taint):
                    taint.update(_target_names(node.target))
            elif isinstance(node, ast.withitem):
                pass
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    if _expr_tainted(gen.iter, taint):
                        taint.update(_target_names(gen.target))
    return taint, params


# -- the rules ----------------------------------------------------------------

def _finding(rule: str, path: str, lines: list[str], node: ast.AST,
             message: str) -> Finding:
    lineno = getattr(node, "lineno", 1)
    line = lines[lineno - 1].strip() if lineno <= len(lines) else ""
    return Finding(rule, path, lineno, getattr(node, "col_offset", 0),
                   message, line)


def rule_hfel001(tree: ast.AST, path: str, lines: list[str]) -> list[Finding]:
    """Unseeded numpy RNG: module-level samplers, or generator constructors
    called without a seed."""
    out: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name is None:
            if isinstance(node.func, ast.Name) and \
                    node.func.id == "default_rng" and not node.args:
                out.append(_finding(
                    "HFEL001", path, lines, node,
                    "default_rng() without a seed — pass an explicit seed "
                    "so runs are reproducible"))
            continue
        if not name.startswith(NP_RANDOM_PREFIXES):
            if isinstance(node.func, ast.Name) and \
                    name == "default_rng" and not node.args:
                out.append(_finding(
                    "HFEL001", path, lines, node,
                    "default_rng() without a seed — pass an explicit seed "
                    "so runs are reproducible"))
            continue
        tail = _tail(name)
        if tail in SEEDED_CTOR_TAILS:
            seeded = bool(node.args) and not (
                isinstance(node.args[0], ast.Constant)
                and node.args[0].value is None)
            seeded = seeded or any(kw.arg == "seed" for kw in node.keywords)
            if not seeded:
                out.append(_finding(
                    "HFEL001", path, lines, node,
                    f"np.random.{tail}() without a seed — pass an explicit "
                    "seed so runs are reproducible"))
        elif tail != "seed":
            out.append(_finding(
                "HFEL001", path, lines, node,
                f"np.random.{tail} uses numpy's module-level RNG state — "
                "use a seeded np.random.default_rng(seed) generator"))
    return out


def rule_hfel002(tree: ast.AST, path: str, lines: list[str]) -> list[Finding]:
    """time.time() — non-monotonic under NTP adjustment; interval timing
    must use time.perf_counter() (pragma genuine wall-clock uses)."""
    out: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and dotted(node.func) == "time.time":
            out.append(_finding(
                "HFEL002", path, lines, node,
                "time.time() is non-monotonic (NTP) — use "
                "time.perf_counter() for intervals, or pragma a genuine "
                "wall-clock use"))
    return out


def rule_hfel003_004(tree: ast.AST, path: str, lines: list[str],
                     scopes: list[JitScope]) -> list[Finding]:
    out: list[Finding] = []
    for scope in scopes:
        taint, params = _scope_taint(scope)
        for node in ast.walk(scope.node):
            # HFEL003: host syncs on traced values
            if isinstance(node, ast.Call):
                name = dotted(node.func)
                if isinstance(node.func, ast.Name) and \
                        node.func.id in HOST_SYNC_BUILTINS and \
                        len(node.args) == 1 and \
                        _expr_tainted(node.args[0], taint):
                    out.append(_finding(
                        "HFEL003", path, lines, node,
                        f"{node.func.id}() on a traced value inside jitted "
                        f"`{scope.node.name}` forces a host sync (or a "
                        "TracerError) — keep it on device"))
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and \
                        _expr_tainted(node.func.value, taint):
                    out.append(_finding(
                        "HFEL003", path, lines, node,
                        ".item() on a traced value inside jitted "
                        f"`{scope.node.name}` forces a host sync"))
                elif name in HOST_SYNC_DOTTED and node.args and \
                        _expr_tainted(node.args[0], taint):
                    out.append(_finding(
                        "HFEL003", path, lines, node,
                        f"{name}() on a traced value inside jitted "
                        f"`{scope.node.name}` pulls the array to host — "
                        "use jnp"))
            # HFEL004: trace-time Python control flow on traced values
            elif isinstance(node, ast.If):
                if _branch_test_tainted(node.test, taint):
                    out.append(_finding(
                        "HFEL004", path, lines, node,
                        "Python `if` on a traced value inside jitted "
                        f"`{scope.node.name}` bakes one branch into the "
                        "program — use jnp.where / lax.cond"))
            elif isinstance(node, ast.While):
                if _branch_test_tainted(node.test, taint):
                    out.append(_finding(
                        "HFEL004", path, lines, node,
                        "Python `while` on a traced value inside jitted "
                        f"`{scope.node.name}` — use lax.while_loop"))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                if _for_iter_flagged(node.iter, taint, params):
                    out.append(_finding(
                        "HFEL004", path, lines, node,
                        "Python `for` over a traced array inside jitted "
                        f"`{scope.node.name}` unrolls at trace time — use "
                        "lax.fori_loop / lax.scan"))
    return out


def _branch_test_tainted(test: ast.expr, taint: set[str]) -> bool:
    # `x is None` / `x is not None` are static trace-time tests even on
    # traced names (they dispatch on presence, not value)
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return False
    if isinstance(test, ast.Call):
        name = dotted(test.func)
        if name in DETAINT_CALLS or _tail(name) in DETAINT_CALLS:
            return False
    return _expr_tainted(test, taint)


def _for_iter_flagged(it: ast.expr, taint: set[str],
                      params: set[str]) -> bool:
    """Direct iteration over a param is the repo's static-structure idiom
    (tuples of per-bucket arrays unroll on static length); only DERIVED
    array taint fires."""
    if isinstance(it, ast.Name):
        return it.id in taint and it.id not in params
    if isinstance(it, ast.Call):
        name = _tail(dotted(it.func))
        if name in ("range", "enumerate", "zip", "reversed", "len"):
            return any(_for_iter_flagged(a, taint, params) for a in it.args)
    return _expr_tainted(it, taint)


def rule_hfel005(tree: ast.AST, path: str, lines: list[str],
                 scopes: list[JitScope]) -> list[Finding]:
    """float64 creep into the float32 kernel/sweep contract."""
    kernel_file = "src/repro/kernels/" in path

    def scan(root: ast.AST, where: str) -> list[Finding]:
        found: list[Finding] = []
        for node in ast.walk(root):
            if isinstance(node, ast.Attribute) and \
                    node.attr in ("float64", "double"):
                found.append(_finding(
                    "HFEL005", path, lines, node,
                    f"{node.attr} in {where} — the kernel/sweep path is "
                    "float32 by parity contract"))
            elif isinstance(node, ast.Constant) and \
                    node.value in ("float64", "f8", ">f8", "<f8"):
                found.append(_finding(
                    "HFEL005", path, lines, node,
                    f"dtype literal {node.value!r} in {where} — the "
                    "kernel/sweep path is float32 by parity contract"))
        return found

    if kernel_file:
        return scan(tree, "kernel code")
    out: list[Finding] = []
    seen: set[tuple[int, int]] = set()
    for scope in scopes:
        for f in scan(scope.node, f"jitted `{scope.node.name}`"):
            if (f.lineno, f.col) not in seen:
                seen.add((f.lineno, f.col))
                out.append(f)
    return out


#: traced-param count at or above which a decorator-jitted function is
#: expected to declare donation (the repo's large-buffer sweeps all qualify)
HFEL006_MIN_TRACED = 4


def rule_hfel006(tree: ast.AST, path: str, lines: list[str],
                 scopes: list[JitScope]) -> list[Finding]:
    out: list[Finding] = []
    for scope in scopes:
        if scope.form != "decorator" or scope.donates:
            continue
        traced, _ = scope.param_split()
        if len(traced) >= HFEL006_MIN_TRACED:
            out.append(_finding(
                "HFEL006", path, lines, scope.node,
                f"jitted `{scope.node.name}` takes {len(traced)} traced "
                "params with no donate_argnums — donate the large resident "
                "buffers or pragma why they must survive the call"))
    return out


#: dotted prefixes (last component) under which a ``.split`` call means the
#: jax PRNG, not array splitting (``jnp.split``/``np.split`` must not fire)
RNG_SPLIT_PREFIXES = {"random", "jrandom", "jr"}


def _axis_diversified(expr: ast.expr, diversified: set[str]) -> bool:
    """True if the expression visibly mixes the mesh position into the key:
    it contains an ``axis_index`` call, or reads a name already derived from
    one."""
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call) and \
                _tail(dotted(sub.func)) == "axis_index":
            return True
        if isinstance(sub, ast.Name) and sub.id in diversified:
            return True
    return False


def rule_hfel007(tree: ast.AST, path: str, lines: list[str],
                 scopes: list[JitScope]) -> list[Finding]:
    """Replicated-key hazard under shard_map: ``jax.random.split`` /
    ``fold_in`` on a key inside a shard_map-traced scope advances the SAME
    stream on every shard unless the mesh position is folded in — code that
    reads as per-shard randomness is silently correlated. An
    ``axis_index``-derived key (directly in the call, or via a name assigned
    from one) is the diversification idiom and exempt; deliberate
    replication takes a ``replicated-key`` pragma."""
    out: list[Finding] = []
    for scope in scopes:
        if not scope.via_shard_map:
            continue
        # names whose values mix in the axis index (two passes approximate
        # the fixpoint, matching _scope_taint)
        diversified: set[str] = set()
        for _ in range(2):
            for node in ast.walk(scope.node):
                if isinstance(node, ast.Assign) and \
                        _axis_diversified(node.value, diversified):
                    for t in node.targets:
                        diversified.update(_target_names(t))
        for node in ast.walk(scope.node):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            tail = _tail(name)
            if tail == "fold_in":
                pass        # fold_in is unique to the jax PRNG
            elif tail == "split" and name != tail and _tail(
                    name.rsplit(".", 1)[0]) in RNG_SPLIT_PREFIXES:
                pass
            else:
                continue
            if any(_axis_diversified(a, diversified) for a in node.args):
                continue    # the key visibly carries the mesh position
            out.append(_finding(
                "HFEL007", path, lines, node,
                f"{tail}() inside shard_map-traced `{scope.node.name}` "
                "without an axis-index fold — every shard advances the SAME "
                "stream; fold in lax.axis_index(axis) to diversify, or "
                "pragma the line if replication is the contract "
                "(replicated-key)"))
    return out


def run_rules(tree: ast.AST, path: str, lines: list[str]) -> list[Finding]:
    scopes = find_jit_scopes(tree)
    out: list[Finding] = []
    out += rule_hfel001(tree, path, lines)
    out += rule_hfel002(tree, path, lines)
    out += rule_hfel003_004(tree, path, lines, scopes)
    out += rule_hfel005(tree, path, lines, scopes)
    out += rule_hfel006(tree, path, lines, scopes)
    out += rule_hfel007(tree, path, lines, scopes)
    return out
