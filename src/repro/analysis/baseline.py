"""Finding baseline: pre-existing findings are recorded with counts and new
ones fail the gate.

The baseline file (``lint_baseline.json`` at the repo root) maps each
:meth:`~repro.analysis.engine.Finding.fingerprint` to the number of times it
occurs plus human-readable context (rule, path, the offending line). The
fingerprint hashes rule + path + stripped source line — not the line NUMBER
— so edits elsewhere in a file don't churn the baseline, while touching the
flagged line itself (or copying it) surfaces as a new finding.

``diff_against_baseline`` returns the findings in EXCESS of the baselined
count per fingerprint: a second identical violation on a new line fails even
though the first is baselined.
"""

from __future__ import annotations

import json
import os
from collections import Counter, defaultdict

from repro.analysis.engine import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = "lint_baseline.json"


def baseline_counts(findings: list[Finding]) -> dict[str, dict]:
    """The JSON-ready baseline body for a findings list."""
    by_fp: dict[str, dict] = {}
    for f in sorted(findings, key=lambda f: (f.path, f.lineno, f.rule)):
        fp = f.fingerprint()
        if fp in by_fp:
            by_fp[fp]["count"] += 1
        else:
            by_fp[fp] = {"rule": f.rule, "path": f.path, "line": f.line,
                         "count": 1}
    return by_fp


def save_baseline(path: str, findings: list[Finding]) -> dict:
    body = {"version": BASELINE_VERSION,
            "findings": baseline_counts(findings)}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(body, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return body


def load_baseline(path: str) -> dict[str, dict]:
    """fingerprint -> entry; a missing file is an empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as fh:
        body = json.load(fh)
    if body.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"unsupported lint baseline version {body.get('version')!r} "
            f"in {path} (expected {BASELINE_VERSION}); regenerate with "
            "scripts/lint.py --fix-baseline")
    return dict(body.get("findings", {}))


def diff_against_baseline(findings: list[Finding],
                          baseline: dict[str, dict]
                          ) -> tuple[list[Finding], list[dict]]:
    """(new findings beyond the baselined counts, stale baseline entries).

    Stale entries — baselined fingerprints no longer (fully) present — are
    informational: the violation was fixed and ``--fix-baseline`` will drop
    the entry."""
    grouped: dict[str, list[Finding]] = defaultdict(list)
    for f in sorted(findings, key=lambda f: (f.path, f.lineno, f.col)):
        grouped[f.fingerprint()].append(f)
    new: list[Finding] = []
    for fp, group in grouped.items():
        allowed = int(baseline.get(fp, {}).get("count", 0))
        if len(group) > allowed:
            new.extend(group[allowed:])
    current = Counter(f.fingerprint() for f in findings)
    stale = [dict(entry, fingerprint=fp)
             for fp, entry in sorted(baseline.items())
             if current[fp] < int(entry.get("count", 0))]
    return sorted(new, key=lambda f: (f.path, f.lineno, f.col)), stale
