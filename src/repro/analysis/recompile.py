"""Recompilation sentinel: capture jax compile events for budget assertions.

The static rules keep the jitted code cache-FRIENDLY; this module checks the
caches actually HIT. With ``jax_log_compiles`` enabled, jax logs one
``"Compiling <name> with global shapes and types ..."`` WARNING per real XLA
compilation (from ``jax._src.interpreters.pxla``); cache hits log nothing.
:class:`CompileLog` attaches a handler to that logger for the duration of a
``with`` block and records each compiled function's name, so a test can
assert a fixed compile budget for a cold-run -> churn -> warm-rerun cycle —
the PR-6 contract that ``_run_device``'s module-global jit cache and
``_SHARDED_CACHE`` make repeat same-shape solves compile-free.

Used by the ``compile_log`` pytest fixture (tests/conftest.py) and the
tier-1 sentinel test (tests/test_recompile_sentinel.py). Unlike the rest of
:mod:`repro.analysis`, this module imports jax — keep it off the
``scripts/lint.py`` fast path.
"""

from __future__ import annotations

import logging
import re

# jax 0.4.x emits compile logs from the pxla module logger; dispatch is
# included defensively for version drift. The regex filter keeps anything
# else those loggers say out of the event list.
_LOGGER_NAMES = ("jax._src.interpreters.pxla", "jax._src.dispatch")
_COMPILE_RE = re.compile(r"^Compiling (\S+)")


class _CompileHandler(logging.Handler):
    def __init__(self, events: list[str]):
        super().__init__(level=logging.DEBUG)
        self._events = events

    def emit(self, record: logging.LogRecord) -> None:
        try:
            m = _COMPILE_RE.match(record.getMessage())
        except Exception:       # never let logging break the program under test
            return
        if m:
            self._events.append(m.group(1))


class CompileLog:
    """Context manager recording one entry per real XLA compilation.

    >>> with CompileLog() as log:
    ...     run_cold()
    ...     log.reset()
    ...     run_warm_again()
    ...     assert log.events == []    # every cache hit
    """

    def __init__(self):
        self.events: list[str] = []
        self._handler: _CompileHandler | None = None
        self._prev_flag: bool | None = None

    def __enter__(self) -> "CompileLog":
        import jax

        self._jax = jax
        self._prev_flag = bool(jax.config.jax_log_compiles)
        jax.config.update("jax_log_compiles", True)
        self._handler = _CompileHandler(self.events)
        self._prev_propagate = {}
        for name in _LOGGER_NAMES:
            lg = logging.getLogger(name)
            lg.addHandler(self._handler)
            # keep the (very chatty) compile logs out of stderr/pytest
            # capture while we listen; restored on exit
            self._prev_propagate[name] = lg.propagate
            lg.propagate = False
        return self

    def __exit__(self, *exc) -> None:
        for name in _LOGGER_NAMES:
            lg = logging.getLogger(name)
            lg.removeHandler(self._handler)
            lg.propagate = self._prev_propagate[name]
        self._jax.config.update("jax_log_compiles", self._prev_flag)

    def reset(self) -> None:
        self.events.clear()

    def count(self, name_substring: str | None = None) -> int:
        """Compile events seen (optionally filtered by function-name
        substring, e.g. ``"_run_device"``)."""
        if name_substring is None:
            return len(self.events)
        return sum(name_substring in e for e in self.events)
