"""hfellint engine: findings, pragma suppression, file walking.

The engine is deliberately stdlib-only (``ast`` + ``hashlib``): linting must
stay cheap enough to run unconditionally at the top of ``scripts/tier1.sh``,
before jax ever imports.

Suppression: a finding is silenced by an inline pragma on its own line or on
the line directly above::

    tmp = f"{int(time.time() * 1e6)}"  # hfellint: disable=HFEL002 -- wall-clock tmp name

The ``-- <justification>`` part is REQUIRED — a pragma without one does not
suppress anything and is itself reported (``HFEL000``), so every baselined
exception carries its reason in the source.
"""

from __future__ import annotations

import ast
import hashlib
import os
import re
from dataclasses import dataclass

PRAGMA_RE = re.compile(
    r"#\s*hfellint:\s*disable=(?P<rules>[A-Z0-9,\s]+?)"
    r"(?:\s*--\s*(?P<why>\S.*))?\s*$")

#: directories never descended into by :func:`lint_paths`
SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules"}


@dataclass(frozen=True)
class Finding:
    """One lint finding, identified across commits by :meth:`fingerprint`."""

    rule: str       # e.g. "HFEL003"
    path: str       # repo-relative, forward slashes
    lineno: int     # 1-based
    col: int        # 0-based
    message: str
    line: str       # the stripped source line (fingerprint component)

    def fingerprint(self) -> str:
        """Line-number-independent identity: rule + path + stripped source
        line. Stable across unrelated edits above/below the finding; two
        identical lines in one file share a fingerprint, which the baseline
        handles by counting."""
        h = hashlib.sha1(
            f"{self.rule}:{self.path}:{self.line}".encode())
        return h.hexdigest()[:16]

    def render(self) -> str:
        return (f"{self.path}:{self.lineno}:{self.col + 1}: "
                f"{self.rule} {self.message}")


def _suppressions(lines: list[str]) -> tuple[dict[int, set[str]],
                                             list[tuple[int, str]]]:
    """(line -> suppressed rule ids, malformed pragmas as (lineno, text)).

    A pragma suppresses its own line; a pragma on a comment-only line also
    suppresses the next line (so long justifications fit above the code)."""
    supp: dict[int, set[str]] = {}
    malformed: list[tuple[int, str]] = []
    for i, raw in enumerate(lines, start=1):
        m = PRAGMA_RE.search(raw)
        if not m:
            continue
        if not m.group("why"):
            malformed.append((i, raw.strip()))
            continue
        rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
        supp.setdefault(i, set()).update(rules)
        if raw.lstrip().startswith("#"):
            supp.setdefault(i + 1, set()).update(rules)
    return supp, malformed


def lint_source(path: str, text: str) -> list[Finding]:
    """Lint one file's source; returns findings sorted by position.

    ``path`` should be repo-relative — it scopes the path-sensitive rules
    (HFEL005 treats everything under ``src/repro/kernels/`` as kernel code)
    and feeds the fingerprint.
    """
    from repro.analysis import rules as _rules

    path = path.replace(os.sep, "/")
    lines = text.splitlines()
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        line = lines[e.lineno - 1].strip() if (
            e.lineno and e.lineno <= len(lines)) else ""
        return [Finding("HFEL000", path, e.lineno or 1, 0,
                        f"file does not parse: {e.msg}", line)]
    findings = _rules.run_rules(tree, path, lines)

    supp, malformed = _suppressions(lines)
    for lineno, pragma in malformed:
        findings.append(Finding(
            "HFEL000", path, lineno, 0,
            "hfellint pragma without a `-- justification`; it suppresses "
            "nothing until a reason is given", pragma))
    out = [f for f in findings
           if f.rule not in supp.get(f.lineno, ()) or f.rule == "HFEL000"]
    return sorted(out, key=lambda f: (f.lineno, f.col, f.rule))


def iter_python_files(targets: list[str], root: str = ".") -> list[str]:
    """Expand files/directories to a sorted repo-relative .py file list."""
    out: set[str] = set()
    for t in targets:
        full = t if os.path.isabs(t) else os.path.join(root, t)
        if os.path.isfile(full):
            out.add(os.path.relpath(full, root))
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in SKIP_DIRS
                                 and not d.startswith("."))
            for name in filenames:
                if name.endswith(".py"):
                    out.add(os.path.relpath(os.path.join(dirpath, name),
                                            root))
    return sorted(p.replace(os.sep, "/") for p in out)


def lint_paths(targets: list[str], root: str = ".") -> list[Finding]:
    """Lint every ``.py`` file under ``targets`` (files or directories),
    resolved relative to ``root``; findings carry root-relative paths."""
    findings: list[Finding] = []
    for rel in iter_python_files(targets, root):
        with open(os.path.join(root, rel), encoding="utf-8") as f:
            text = f.read()
        findings.extend(lint_source(rel, text))
    return findings
