from repro.data.federated import (FederatedDataset, make_femnist_like,
                                  make_mnist_like, partition_power_law)
from repro.data.tokens import TokenPipeline

__all__ = ["FederatedDataset", "make_femnist_like", "make_mnist_like",
           "partition_power_law", "TokenPipeline"]
