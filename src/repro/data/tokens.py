"""Deterministic synthetic token pipeline for LM training examples.

Host-sharded: each process materializes only its slice of the global batch
(``process_index``/``process_count``), the pattern a real multi-pod loader
follows. Sequences follow a Zipfian unigram draw with Markov bigram
structure so the loss has signal to descend.
"""

from __future__ import annotations

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, process_index: int = 0, process_count: int = 1):
        assert global_batch % process_count == 0
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.local_batch = global_batch // process_count
        self._rng = np.random.default_rng(seed + 7919 * process_index)
        # Zipf unigram + shared bigram shift structure
        ranks = np.arange(1, vocab_size + 1)
        self._p = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = np.random.default_rng(seed).integers(
            1, vocab_size, size=vocab_size)

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        """(local_batch, seq_len + 1) int32 tokens."""
        b, s = self.local_batch, self.seq_len + 1
        first = self._rng.choice(self.vocab, size=(b, 1), p=self._p)
        noise = self._rng.random((b, s - 1)) < 0.25
        out = np.empty((b, s), np.int64)
        out[:, 0] = first[:, 0]
        for t in range(1, s):
            nxt = self._shift[out[:, t - 1]] % self.vocab
            rand = self._rng.choice(self.vocab, size=b, p=self._p)
            out[:, t] = np.where(noise[:, t - 1], rand, nxt)
        return out.astype(np.int32)
