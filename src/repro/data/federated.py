"""Synthetic federated classification datasets matching the paper's §V.B
protocol: MNIST-like (10 classes) and FEMNIST-like (62 classes), partitioned
non-IID — each client holds only ``labels_per_client`` labels, with
power-law sample counts (per [20] Li et al.). 75/25 train/test split.

No external downloads (offline container): inputs are drawn from per-class
Gaussian prototypes with within-class structure, which preserves everything
the paper's experiments measure (relative convergence of HFEL vs FedAvg
under non-IID client skew), if not absolute MNIST accuracy.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class FederatedDataset:
    client_x: np.ndarray      # (N_clients, max_samples, dim) padded
    client_y: np.ndarray      # (N_clients, max_samples) int, -1 = pad
    client_sizes: np.ndarray  # (N_clients,)
    test_x: np.ndarray
    test_y: np.ndarray
    n_classes: int

    @property
    def n_clients(self) -> int:
        return self.client_x.shape[0]

    @property
    def dim(self) -> int:
        return self.client_x.shape[-1]


def partition_power_law(n_total: int, n_clients: int, *, alpha: float = 2.0,
                        min_size: int = 20, rng=None) -> np.ndarray:
    """Power-law client sample counts summing to ~n_total."""
    rng = rng or np.random.default_rng(0)
    raw = rng.pareto(alpha, n_clients) + 1.0
    sizes = np.maximum((raw / raw.sum() * n_total).astype(int), min_size)
    return sizes


def _make_classification(n_clients: int, n_classes: int, dim: int, *,
                         labels_per_client: int, samples_total: int,
                         class_sep: float, seed: int) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, (n_classes, dim)) * class_sep / np.sqrt(dim)
    # shared within-class covariance structure + heavy isotropic overlap
    mix = rng.normal(0.0, 1.0, (dim, dim)) / np.sqrt(dim)

    def sample(cls, n):
        z = rng.normal(0.0, 1.0, (n, dim))
        return (protos[cls][None, :] + z @ mix).astype(np.float32)

    sizes = partition_power_law(samples_total, n_clients, rng=rng)
    max_size = int(sizes.max())
    cx = np.zeros((n_clients, max_size, dim), np.float32)
    cy = np.full((n_clients, max_size), -1, np.int32)
    for c in range(n_clients):
        labels = rng.choice(n_classes, labels_per_client, replace=False)
        per = np.array_split(np.arange(sizes[c]), labels_per_client)
        for lbl, idx in zip(labels, per):
            cx[c, idx] = sample(lbl, len(idx))
            cy[c, idx] = lbl

    n_test = max(samples_total // 4, n_classes * 20)
    ty = rng.integers(0, n_classes, n_test).astype(np.int32)
    tx = np.concatenate([sample(int(l), 1) for l in ty], axis=0)
    return FederatedDataset(cx, cy, sizes.astype(np.float32), tx, ty,
                            n_classes)


def make_mnist_like(n_clients: int = 30, *, dim: int = 64,
                    samples_total: int = 6000, seed: int = 0) -> FederatedDataset:
    """10 classes, 2 labels per client (the paper's MNIST protocol)."""
    return _make_classification(n_clients, 10, dim, labels_per_client=2,
                                samples_total=samples_total, class_sep=2.0,
                                seed=seed)


def make_femnist_like(n_clients: int = 30, *, dim: int = 64,
                      samples_total: int = 9000, seed: int = 0) -> FederatedDataset:
    """62 classes, 8 labels per client (FEMNIST-flavoured heterogeneity)."""
    return _make_classification(n_clients, 62, dim, labels_per_client=8,
                                samples_total=samples_total, class_sep=2.5,
                                seed=seed)
