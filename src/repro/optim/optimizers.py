"""Optimizers from scratch (no optax): SGD(+momentum), AdamW, global-norm
clipping. The interface mirrors the (init, update) transformation style so
optimizers compose with the FL runtime and the pjit train step."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import tree_global_norm


class Optimizer(NamedTuple):
    init: Callable
    update: Callable      # (grads, state, params, step) -> (updates, state)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else lr


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr_t * g, grads), ()
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr_t * (momentum * m + g),
                               new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr_t * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        t = step + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(m_, v_, p):
            step_ = m_ / bc1 / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (-lr_t * step_).astype(p.dtype)

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params, step):
        norm = tree_global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        clipped = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return opt.update(clipped, state, params, step)

    return Optimizer(opt.init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
