"""Learning-rate schedules (callables of the step index)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(peak: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))

    return fn


def linear_warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                         floor: float = 0.0):
    cos = cosine_decay(peak, max(total_steps - warmup_steps, 1), floor)

    def fn(step):
        warm = peak * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return fn
