"""Architecture registry: one module per assigned architecture.

``get_config(name)`` accepts either the registry id (``qwen3-0.6b``) or the
module name (``qwen3_0p6b``).
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "olmo-1b": "olmo_1b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-0.6b": "qwen3_0p6b",
    "qwen3-32b": "qwen3_32b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "internvl2-1b": "internvl2_1b",
    "mamba2-1.3b": "mamba2_1p3b",
    "zamba2-2.7b": "zamba2_2p7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    module_name = _MODULES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{module_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {arch: get_config(arch) for arch in ARCH_IDS}
