"""zamba2-2.7b [hybrid] — arXiv:2411.15242. Mamba2 backbone with one
weight-tied (shared) attention+MLP block applied every 6 layers."""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,                  # shared attention block's MLP width
    vocab_size=32000,
    hybrid_attn_period=6,
    ssm=SSMConfig(
        state_size=64,
        head_dim=64,
        n_groups=1,
        conv_kernel=4,
        expand=2,
        chunk_size=256,
    ),
)
