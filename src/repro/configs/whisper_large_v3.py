"""whisper-large-v3 [audio] — arXiv:2212.04356 (backbone only; conv/mel
frontend is a stub supplying precomputed frame embeddings)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,                 # decoder depth
    n_encoder_layers=32,
    encoder_seq_len=1500,        # 30 s of audio after 2x conv downsampling
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    norm_type="layernorm",
    mlp_type="gelu",
    qkv_bias=True,
    use_rope=False,              # sinusoidal (enc) + learned (dec) positions
    tie_embeddings=True,
    max_seq_len=32_768,
)
