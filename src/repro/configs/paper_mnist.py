"""The paper's own learning task (§V): multinomial logistic regression /
small MLP over MNIST-like federated data, trained full-batch.

This config drives the FL simulation stack (repro.fl), not the LM zoo:
use ``repro.fl.train_federated`` / ``benchmarks.paper_training``.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class PaperTaskConfig:
    model: str = "mlr"            # "mlr" (paper's convex task) or "mlp"
    dataset: str = "mnist"        # "mnist" (10-way) or "femnist" (62-way)
    n_devices: int = 30
    n_servers: int = 5
    local_iters: int = 10         # L(theta)
    edge_iters: int = 5           # I(eps, theta)
    global_rounds: int = 1000     # paper's §V.B budget
    lr: float = 1e-4              # paper Table II learning rate


CONFIG = PaperTaskConfig()
