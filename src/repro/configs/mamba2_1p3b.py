"""mamba2-1.3b [ssm] — arXiv:2405.21060. SSD, attention-free."""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,                  # d_inner / ssm.head_dim (bookkeeping only)
    n_kv_heads=64,
    d_ff=0,                      # attention-free: no MLP blocks
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(
        state_size=128,
        head_dim=64,
        n_groups=1,
        conv_kernel=4,
        expand=2,
        chunk_size=256,
    ),
)
