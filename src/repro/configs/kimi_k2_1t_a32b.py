"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE (paper-table config).

Assigned spec: 61L, d_model=7168, 64H (GQA kv=8), expert width d_ff=2048,
vocab=163840, 384 routed experts top-8. We add 1 shared expert and 1 leading
dense layer (width 18432) following the public K2 architecture family; the
assignment's GQA attention is used as specified (public K2 uses MLA — noted
in DESIGN.md §Arch-applicability)."""

from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18432,                  # leading dense layer width
    vocab_size=163840,
    rope_theta=50_000.0,
    moe=MoEConfig(
        n_experts=384,
        top_k=8,
        d_expert=2048,
        n_shared=1,
        n_dense_layers=1,
        capacity_factor=1.25,
    ),
)
