"""internvl2-1b [vlm] — arXiv:2404.16821. Qwen2-0.5B LM backbone; the
InternViT frontend is a stub supplying precomputed patch embeddings."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    n_vision_tokens=256,
)
