"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434.

MLA with kv_lora_rank=512; 64 routed experts (top-6) + 2 shared, expert
width 1408; first layer dense (width 10944). The assignment note mentions
"160 routed" (the non-Lite V2); the Lite HF config has 64 routed — we follow
the assigned "MoE 64e top-6"."""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                  # leading dense layer width
    vocab_size=102400,
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        n_dense_layers=1,
        capacity_factor=1.25,
    ),
)
