"""Fault tolerance & elasticity for the HFEL runtime.

Three mechanisms, all driven by the paper's own cost machinery:

* :class:`StragglerPolicy` — the optimal resource allocation equalizes
  finish times at a deadline t* (Section III KKT structure); the runtime
  enforces that deadline. Participants whose realized round time exceeds
  ``slack * t*`` are dropped from the round and eq. (8)'s weights are
  renormalized over survivors.

* :class:`FailureInjector` — Bernoulli node failures (and recoveries) per
  round, for integration tests and chaos benchmarks.

* :class:`ElasticReassociator` — on membership change, re-runs edge
  association *warm-started from the current stable point* (Alg. 3
  restricted to the perturbed state converges in a handful of adjustments —
  Thm. 3's argument applies from any initial strategy).

Plus :func:`retry_with_backoff` for transient launcher failures.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.edge_association import AssociationEngine, AssociationResult
from repro.core.scenario import Scenario


@dataclass
class StragglerPolicy:
    """Deadline-based straggler mitigation.

    ``deadline``: the scheduler's t* (seconds). ``slack``: multiplicative
    grace factor. ``mask(times)`` returns the participation mask for the
    round; aggregation weight renormalization happens in the trainer (its
    weighted means already honour the mask).
    """

    deadline: float
    slack: float = 1.10
    min_participants: int = 1

    def mask(self, realized_times: np.ndarray) -> np.ndarray:
        keep = realized_times <= self.deadline * self.slack
        if keep.sum() < self.min_participants:
            order = np.argsort(realized_times)
            keep = np.zeros_like(keep)
            keep[order[:self.min_participants]] = True
        return keep


class FailureInjector:
    """Per-round Bernoulli failures with geometric recovery."""

    def __init__(self, n_nodes: int, *, p_fail: float = 0.02,
                 p_recover: float = 0.5, seed: int = 0):
        self.alive = np.ones(n_nodes, bool)
        self.p_fail = p_fail
        self.p_recover = p_recover
        self.rng = np.random.default_rng(seed)

    def step(self) -> np.ndarray:
        dies = self.rng.random(self.alive.shape) < self.p_fail
        recovers = self.rng.random(self.alive.shape) < self.p_recover
        self.alive = np.where(self.alive, ~dies, recovers)
        return self.alive.copy()


class ElasticReassociator:
    """Incremental edge re-association on node arrival/departure."""

    def __init__(self, sc: Scenario, *, kind: str = "fast", seed: int = 0):
        self.sc = sc
        self.kind = kind
        self.seed = seed
        self.current: AssociationResult | None = None

    def initial(self) -> AssociationResult:
        eng = AssociationEngine(self.sc, kind=self.kind, seed=self.seed)
        self.current = eng.run_batched("nearest")
        return self.current

    def on_membership_change(self, alive: np.ndarray) -> AssociationResult:
        """Re-associate with dead devices pinned out of every group.

        Dead devices keep an assignment slot (arrays stay fixed-size for the
        jitted solvers) but are excluded via the availability matrix and a
        zero-cost pin to their nearest server; live devices warm-start from
        the current stable assignment.
        """
        import copy

        sc = copy.copy(self.sc)
        avail = self.sc.avail.copy()
        # dead devices are only "available" to a dummy nearest server so they
        # never enter a live group's cost
        nearest = np.argmin(self.sc.dist, axis=0)
        dead = ~alive
        avail[:, dead] = False
        avail[nearest[dead], dead] = True
        sc.avail = avail

        eng = AssociationEngine(sc, kind=self.kind, seed=self.seed)
        warm = (self.current.assignment.copy() if self.current is not None
                else eng.initial_assignment("nearest"))
        warm[dead] = nearest[dead]
        res = eng.run_batched(assignment=warm)
        self.current = res
        return res


def retry_with_backoff(fn, *, max_attempts: int = 5, base_delay: float = 0.5,
                       retry_on: tuple = (RuntimeError, OSError),
                       sleep=time.sleep):
    """Launcher helper: call fn() with exponential backoff on failure."""
    last = None
    for attempt in range(max_attempts):
        try:
            return fn()
        except retry_on as e:          # noqa: PERF203
            last = e
            sleep(base_delay * (2 ** attempt))
    raise last
