from repro.runtime.fault_tolerance import (ElasticReassociator,
                                           FailureInjector, StragglerPolicy,
                                           retry_with_backoff)

__all__ = ["ElasticReassociator", "FailureInjector", "StragglerPolicy",
           "retry_with_backoff"]
