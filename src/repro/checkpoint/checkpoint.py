"""Checkpoint/restart for fault-tolerant training.

Format: one directory per step containing
  * ``manifest.json``  — step, tree structure (paths + shapes + dtypes),
    mesh metadata, user extras
  * ``shard_<i>.npz``  — leaf arrays, chunked so no single file exceeds
    ``max_shard_bytes`` (multi-host object stores dislike huge blobs)

Writes are atomic (tmp dir + rename) and optionally asynchronous (a
background thread snapshots device arrays to host first, so the training
loop never blocks on disk). Restore rebuilds the pytree and can re-shard
onto a *different* mesh (elastic restart) by passing ``shardings``.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _key_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    extras: dict | None = None,
                    max_shard_bytes: int = 1 << 30) -> str:
    """Synchronous atomic save; returns the checkpoint path."""
    leaves_with_paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = [_key_str(p) for p, _ in leaves_with_paths]
    arrays = [np.asarray(v) for _, v in leaves_with_paths]

    final = os.path.join(directory, f"step_{step:010d}")
    # hfellint: disable=HFEL002 -- wall-clock uniqueness token, not an interval
    tmp = final + f".tmp.{os.getpid()}.{int(time.time() * 1e6)}"
    os.makedirs(tmp, exist_ok=True)

    shards: list[dict[str, np.ndarray]] = [{}]
    sizes = [0]
    index = {}
    for name, arr in zip(names, arrays):
        if sizes[-1] + arr.nbytes > max_shard_bytes and shards[-1]:
            shards.append({})
            sizes.append(0)
        shard_id = len(shards) - 1
        shards[shard_id][name] = arr
        sizes[-1] += arr.nbytes
        index[name] = {"shard": shard_id, "shape": list(arr.shape),
                       "dtype": str(arr.dtype)}

    for i, shard in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i}.npz"),
                 **{k.replace("/", "\x1f"): v for k, v in shard.items()})
    manifest = {"step": step, "index": index, "n_shards": len(shards),
                "extras": extras or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, *, step: int | None = None,
                    template: Any | None = None, shardings: Any | None = None):
    """Load the latest (or given) step. Returns (step, tree, extras).

    ``template``: a pytree whose structure the restored leaves are unflattened
    into (required — names alone do not determine structure).
    ``shardings``: optional matching pytree of NamedSharding for elastic
    restore onto a new mesh via jax.device_put.
    """
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith("tmp"))
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = step if step is not None else steps[-1]
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    data = {}
    for i in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{i}.npz")) as z:
            for k in z.files:
                data[k.replace("\x1f", "/")] = z[k]

    if template is None:
        return step, data, manifest["extras"]

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = [data[_key_str(p)] for p, _ in leaves_with_paths]
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return step, tree, manifest["extras"]


class CheckpointManager:
    """Keep-last-k async checkpointer."""

    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, *, extras: dict | None = None):
        # snapshot to host first so training can proceed
        host_tree = jax.tree.map(np.asarray, tree)
        self.wait()

        def work():
            save_checkpoint(self.directory, step, host_tree, extras=extras)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template=None, *, step=None, shardings=None):
        self.wait()
        return load_checkpoint(self.directory, step=step, template=template,
                               shardings=shardings)

    def latest_step(self) -> int | None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and "tmp" not in d)
        return steps[-1] if steps else None

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and "tmp" not in d)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
