"""Live HFEL co-simulation: elastic edge re-association DURING federated
training.

The paper treats edge association and training as one system — the
association policy exists to cut the cost of the training rounds it
schedules — and this module finally runs them as one program: a
:class:`LiveHFELRunner` drives :class:`repro.fl.training.FederatedTrainer`
rounds while the :class:`repro.core.scenario.Scenario` churns underneath it.
Every global round

1. applies one seeded :func:`repro.core.scenario.perturb_scenario` tick
   (mobility drift, reach flips, arrivals/departures),
2. re-solves the edge association via a pluggable policy (below),
3. repairs the trainer's state for the churn — ``Scenario.active`` maps onto
   the trainer's ``client_mask`` through a
   :class:`repro.core.scenario.DeviceClientBridge`, departed devices are
   parked (masked out of aggregation but kept in the fixed-size arrays), and
   arrivals are re-admitted with their edge's CURRENT parameters
   (:meth:`FederatedTrainer.readmit_clients`),
4. hot-swaps the assignment between cloud aggregations (the swap point where
   the global weighted mean is invariant to the grouping — the property-test
   contract in ``tests/test_fl_training.py``), and
5. accumulates the paper's global system cost (eq. 17) for the round's
   assignment on the round's scenario, next to training accuracy.

Re-association policies
-----------------------
``static``
    The round-0 stable assignment is frozen; churn only ever triggers the
    minimal feasibility repair (:func:`repro.core.assoc_fast.repair_assignment`
    — departures park, unreachable devices fall to their nearest reachable
    server) with ZERO descent moves. The baseline the paper's premise says
    should lose under mobility.
``periodic-cold``
    Every ``resolve_every`` rounds, a FRESH engine is built on the churned
    scenario (full reach-map + toggle-cache rebuild) and descends from the
    repaired previous stable point.
``incremental-warm``
    Every ``resolve_every`` rounds, the round-0 engine's
    :meth:`~repro.core.assoc_fast.FastAssociationEngine.rerun_incremental`
    re-converges from the SAME repaired stable point, but with patched
    slot-index maps and a stale-row-only toggle-cache refresh.

Every timed solve (round-0, cold, warm) runs with ``finalize=False`` — the
non-verifying fast path returning just the assignment — so the association
timers are symmetric across policies: cost accounting happens exactly once
per round for every policy, on the shared reference-accuracy evaluator
(:func:`~repro.core.assoc_fast.assignment_true_cost`), OUTSIDE the
association timer.

Because ``periodic-cold`` descends from exactly the assignment
``incremental-warm`` repairs to (both via :func:`repair_assignment`, from
the same last-swap stable point and active mask), the PR-4 warm/cold parity
gate applies at EVERY swap point: the two policies must produce
bit-identical assignments round for round, while the warm policy spends
measurably less association wall time. ``run_live(verify=True)`` turns on
the engine-level parity assertion inside each warm re-solve as well.

Multi-tick deltas: when ``resolve_every > 1`` the scenario churns between
re-solves; the runner hands ``rerun_incremental`` the single combined
:func:`repro.core.scenario.diff_scenarios` delta between the last-swap
scenario and the current one, so one incremental re-solve absorbs any
number of ticks.

Streaming admission under capacities
------------------------------------
When the scenario carries per-edge caps (``Scenario.max_devices``), the
runner splits the world in two: the TRUE scenario keeps churning (its
``active`` mask says who *wants* to train), while the association stack only
ever sees the admitted *view* (``active`` = the admitted subset). Admission
is an O(K)-per-device greedy nearest-feasible placement
(:func:`repro.core.edge_association.greedy_admission`) that runs WITHOUT
waking the solver: arrivals land in a bounded FIFO overflow queue, an
admission tick drains it against current loads every round, and re-solve
rounds drain it again AFTER the global descent (the post-resolve drain) —
turning ``rerun_incremental`` from a batch-tick API into the periodic
global pass of an online service loop. A device the capacitated repair
cannot place (its reachable servers are all at cap) is demoted back to the
queue instead of crashing the round; when the queue overflows
``overflow_max``, the oldest entries are dropped and counted as rejected
(they re-enter only by departing and re-arriving in the true scenario).
Swap references are stored BEFORE the drain, so the warm/cold parity
contract above survives capacities: both policies descend from the same
pre-drain stable state. With no caps, none of this machinery is
instantiated and the historical behavior is untouched.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.assoc_fast import (DEFAULT_EXCHANGE_SAMPLES,
                                   FastAssociationEngine,
                                   assignment_true_cost, repair_assignment)
from repro.core.edge_association import (GroupSolver, NoFeasibleServerError,
                                         greedy_admission)
from repro.core.scenario import (DeviceClientBridge, Scenario,
                                 device_client_bridge, diff_scenarios,
                                 perturb_scenario)
from repro.data.federated import FederatedDataset
from repro.fl.training import TrainHistory, train_federated

POLICIES = ("static", "periodic-cold", "incremental-warm")

# one mild mobility tick per global round: 5% of devices drift, 2% lose a
# reach bit, 2% depart, 10% of the inactive pool returns — the operating
# regime of the churn benchmark (assoc_scale/churn), scaled to per-round use
DEFAULT_CHURN = {"drift_m": 60.0, "move_frac": 0.05, "flip_frac": 0.02,
                 "depart_frac": 0.02, "arrive_frac": 0.10}


@dataclass
class LiveHistory:
    """Per-round record of one live co-simulation.

    The round-indexed lists always have length ``rounds`` regardless of
    ``eval_every`` (training metrics live in ``train``, whose lists carry
    their own ``eval_rounds`` index). ``swap_rounds``/``swap_assignments``
    record every hot-swap, round 0's initial solve included."""

    policy: str
    resolve_every: int
    # -- round-indexed (length == rounds) --
    system_cost: list = field(default_factory=list)     # eq. (17)
    system_energy: list = field(default_factory=list)   # eq. (15)
    system_delay: list = field(default_factory=list)    # eq. (16)
    assoc_seconds: list = field(default_factory=list)
    swapped: list = field(default_factory=list)
    moves: list = field(default_factory=list)
    n_active: list = field(default_factory=list)
    n_arrived: list = field(default_factory=list)
    n_departed: list = field(default_factory=list)
    # -- streaming admission (all zero when the scenario has no caps) --
    n_queued: list = field(default_factory=list)     # queue depth at round end
    n_admitted: list = field(default_factory=list)   # streamed in this round
    n_rejected: list = field(default_factory=list)   # dropped from the queue
    # -- swap-indexed --
    swap_rounds: list = field(default_factory=list)
    swap_assignments: list = field(default_factory=list)
    train: TrainHistory | None = None

    @property
    def rounds(self) -> int:
        return len(self.system_cost)

    @property
    def cumulative_cost(self) -> float:
        """Sum of the per-round eq.-(17) costs — the figure of merit the
        re-association policies compete on."""
        return float(np.sum(self.system_cost))

    @property
    def assoc_seconds_total(self) -> float:
        return float(np.sum(self.assoc_seconds))

    def as_dict(self) -> dict:
        """JSON-friendly summary (per-swap assignments are kept only as
        counts; the arrays themselves stay on the object)."""
        return {
            "policy": self.policy, "resolve_every": self.resolve_every,
            "rounds": self.rounds,
            "system_cost": [float(c) for c in self.system_cost],
            "system_energy": [float(c) for c in self.system_energy],
            "system_delay": [float(c) for c in self.system_delay],
            "cumulative_cost": self.cumulative_cost,
            "assoc_seconds": [float(s) for s in self.assoc_seconds],
            "assoc_seconds_total": self.assoc_seconds_total,
            "swapped": [bool(s) for s in self.swapped],
            "moves": [int(m) for m in self.moves],
            "n_active": [int(a) for a in self.n_active],
            "n_arrived": [int(a) for a in self.n_arrived],
            "n_departed": [int(d) for d in self.n_departed],
            "n_queued": [int(q) for q in self.n_queued],
            "n_admitted": [int(a) for a in self.n_admitted],
            "n_rejected": [int(x) for x in self.n_rejected],
            "swap_rounds": [int(r) for r in self.swap_rounds],
            "train": self.train.as_dict() if self.train is not None else None,
        }


class LiveHFELRunner:
    """The round policy object behind :func:`run_live` — usable directly as
    ``train_federated(..., round_hook=runner)``.

    ``begin_round(trainer, r)`` performs the full churn/re-associate/repair
    step described in the module docstring and returns the round's
    (n_clients,) assignment. State between rounds: the current scenario,
    the device-axis assignment, and (for ``incremental-warm``) the live
    association engine with its toggle-cache warm state.
    """

    def __init__(self, sc: Scenario, n_clients: int, *,
                 policy: str = "incremental-warm", resolve_every: int = 1,
                 churn: dict | None = None, seed: int = 0,
                 kind: str = "fast", profile: str = "coarse",
                 rel_tol: float = 1e-3, compact: bool | str = "auto",
                 shards: int | None = None, ra_backend: str = "xla",
                 max_moves: int = 10_000,
                 exchange_samples: int = DEFAULT_EXCHANGE_SAMPLES,
                 verify: bool = False, overflow_max: int = 64,
                 bridge: DeviceClientBridge | None = None):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        if resolve_every < 1:
            raise ValueError("resolve_every must be >= 1")
        if overflow_max < 0:
            raise ValueError("overflow_max must be >= 0")
        # -- streaming admission state (only instantiated under caps): the
        # TRUE scenario churns; the association stack sees the admitted view
        self.sc = sc
        self._sc_full = sc
        self._cap = sc.capacity
        self.overflow_max = overflow_max
        self._queue: list[int] = []
        self._round_rejected = 0
        self._admitted: np.ndarray | None = None
        if self._cap is not None:
            admitted = sc.active_mask.copy()
            act = np.flatnonzero(admitted)
            load = np.zeros(sc.n_servers, dtype=np.int64)
            placed = greedy_admission(sc.dist, sc.eff_avail, load,
                                      self._cap, act)
            refused = act[placed < 0]
            admitted[refused] = False
            self._admitted = admitted
            self._queue = refused.tolist()
            self._round_rejected = self._trim_queue()
            self.sc = dataclasses.replace(sc, active=admitted.copy())
        self.policy = policy
        self.resolve_every = resolve_every
        self.churn = dict(DEFAULT_CHURN if churn is None else churn)
        self.seed = seed
        self.kind = kind
        self.profile = profile
        self.rel_tol = rel_tol
        self.compact = compact
        self.shards = shards
        self.ra_backend = ra_backend
        self.max_moves = max_moves
        self.exchange_samples = exchange_samples
        self.verify = verify
        self.bridge = bridge or device_client_bridge(sc, n_clients)
        if self.bridge.n_devices != sc.n_devices:
            raise ValueError("bridge does not match the scenario's device axis")
        if self.bridge.n_clients != n_clients:
            raise ValueError(
                f"bridge maps {self.bridge.n_clients} clients but the "
                f"dataset has {n_clients}")
        # reference-accuracy cost evaluator, shared by every policy and kept
        # OUT of the association timer; valid across churn because device/
        # server physical params are perturbation-invariant ("proportional"
        # reads distances, so it rebuilds per round)
        self._eval_solver = (None if kind == "proportional" else
                             GroupSolver(sc, kind, seed=seed,
                                         profile="default"))
        self.engine: FastAssociationEngine | None = None
        self.assignment: np.ndarray | None = None   # device axis, parked incl.
        # all association-side round state tracks the VIEW (self.sc), which
        # equals the true scenario whenever there are no caps
        self._active_prev = self.sc.active_mask.copy()
        self._sc_at_swap = self.sc
        self._active_at_swap = self.sc.active_mask.copy()
        self._assign_at_swap: np.ndarray | None = None
        self.history = LiveHistory(policy=policy, resolve_every=resolve_every)

    # -- internals -----------------------------------------------------------

    def _tick_seed(self, r: int) -> int:
        # deterministic per (seed, round); identical across policies so every
        # policy sees the exact same churn trajectory
        return (self.seed + 1) * 1_000_003 + r

    def _new_engine(self, sc: Scenario) -> FastAssociationEngine:
        return FastAssociationEngine(sc, kind=self.kind, seed=self.seed,
                                     rel_tol=self.rel_tol,
                                     profile=self.profile,
                                     compact=self.compact,
                                     shards=self.shards,
                                     ra_backend=self.ra_backend)

    # -- streaming admission (capacitated scenarios only) --------------------

    def _rebuild_view(self) -> None:
        self.sc = dataclasses.replace(self._sc_full,
                                      active=self._admitted.copy())

    def _trim_queue(self) -> int:
        """Bound the overflow queue: drop the OLDEST entries beyond
        ``overflow_max`` (they starved longest and their demand is stalest;
        they re-enter only by departing and re-arriving in the true
        scenario). Returns the number dropped."""
        drop = len(self._queue) - self.overflow_max
        if drop > 0:
            self._queue = self._queue[drop:]
        return max(drop, 0)

    def _admission_tick(self) -> int:
        """Drain the overflow queue greedily against CURRENT loads — the
        O(K)-per-device streaming admission path; no solver involvement.
        Admitted devices enter the view and take their placement directly
        in ``self.assignment``; the rest stay queued in FIFO order."""
        if not self._queue:
            return 0
        k = self._sc_full.n_servers
        load = np.bincount(self.assignment[self._admitted], minlength=k)
        devices = np.asarray(self._queue, dtype=np.int64)
        placed = greedy_admission(self._sc_full.dist, self._sc_full.eff_avail,
                                  load, self._cap, devices)
        got = placed >= 0
        if got.any():
            self.assignment[devices[got]] = placed[got]
            self._admitted[devices[got]] = True
            self._queue = devices[~got].tolist()
            self._rebuild_view()
        return int(got.sum())

    def _repair_with_demotions(self, prev_assign: np.ndarray,
                               old_active: np.ndarray) -> np.ndarray:
        """Capacitated host repair with overflow demotion: a device
        :func:`repair_assignment` cannot place (every reachable server at
        cap) is demoted from the admitted view into the queue and the
        repair re-runs on the shrunk view. Pre-validating here — BEFORE
        any engine call — matters because the engine mutates its reach
        maps before repairing; by the time its internal (deterministic,
        input-identical) repair runs, this loop has guaranteed it
        succeeds. Terminates: every retry strictly shrinks the admitted
        set. Leaves ``self.sc`` as the final view."""
        while True:
            self._rebuild_view()
            try:
                assign, *_ = repair_assignment(self.sc, prev_assign,
                                               old_active)
                return assign
            except NoFeasibleServerError as e:
                self._admitted[e.devices] = False
                self._queue.extend(int(d) for d in e.devices)

    def _record(self, *, assoc_s: float, swapped: bool, moves: int,
                arrived: int, departed: int, admitted: int = 0) -> None:
        h = self.history
        # _eval_solver is None for "proportional" (distance-dependent):
        # assignment_true_cost then builds a fresh per-round solver itself
        e, t, c = assignment_true_cost(self.sc, self.assignment,
                                       solver=self._eval_solver,
                                       kind=self.kind, seed=self.seed)
        h.system_cost.append(c)
        h.system_energy.append(e)
        h.system_delay.append(t)
        h.assoc_seconds.append(assoc_s)
        h.swapped.append(swapped)
        h.moves.append(moves)
        h.n_active.append(int(self.sc.active_mask.sum()))
        h.n_arrived.append(arrived)
        h.n_departed.append(departed)
        h.n_queued.append(len(self._queue))
        h.n_admitted.append(admitted)
        h.n_rejected.append(self._round_rejected)
        self._round_rejected = 0
        if swapped:
            h.swap_rounds.append(len(h.system_cost) - 1)
            h.swap_assignments.append(self.assignment.copy())

    # -- the round policy ----------------------------------------------------

    def begin_round(self, trainer, r: int):
        if r == 0:
            trainer.client_mask = jnp.asarray(
                self.bridge.client_mask(self.sc.active_mask))
            t0 = time.perf_counter()
            self.engine = self._new_engine(self.sc)
            assignment = self.engine.run(
                "nearest", max_moves=self.max_moves,
                exchange_samples=self.exchange_samples, finalize=False)
            assoc_s = time.perf_counter() - t0
            self.assignment = np.asarray(assignment)
            self._assign_at_swap = self.assignment.copy()
            self._record(assoc_s=assoc_s, swapped=True,
                         moves=self.engine.last_moves, arrived=0, departed=0)
            if self.policy != "incremental-warm":
                # only the warm policy re-enters the engine (toggle caches,
                # reach maps, device buffers) after round 0 — don't keep
                # that state resident for the whole run under the others
                self.engine = None
            return self.bridge.client_assignment(self.assignment)

        capped = self._admitted is not None
        if capped:
            admitted_before = self._admitted.copy()
            self._sc_full, delta = perturb_scenario(
                self._sc_full, seed=self._tick_seed(r), **self.churn)
            full_active = self._sc_full.active_mask
            # true-scenario departures leave the admitted set and the queue;
            # arrivals join the queue — streaming admission is the ONLY path
            # into the training population under caps
            self._admitted &= full_active
            self._queue = [d for d in self._queue if full_active[d]]
            self._queue.extend(np.flatnonzero(delta.arrived).tolist())
            self._rebuild_view()
        else:
            self.sc, delta = perturb_scenario(self.sc,
                                              seed=self._tick_seed(r),
                                              **self.churn)
        assoc_s, moves, swapped, admitted_n = 0.0, 0, False, 0
        resolve = self.policy != "static" and r % self.resolve_every == 0
        if resolve and self.policy == "incremental-warm":
            # the delta derivation is part of the warm path's per-swap work,
            # so it belongs inside the association timer (cold's timer
            # likewise spans its repair + engine build)
            t0 = time.perf_counter()
            if capped:
                # pre-validate the engine's repair inputs: demote devices
                # the capacitated repair cannot place, so the engine's own
                # (deterministic, input-identical) repair cannot raise
                self._repair_with_demotions(self.engine.stable_assignment,
                                            self._active_at_swap)
            combined = diff_scenarios(self._sc_at_swap, self.sc)
            self.assignment = self.engine.rerun_incremental(
                self.sc, combined, max_moves=self.max_moves,
                exchange_samples=self.exchange_samples, verify=self.verify,
                finalize=False)
            assoc_s = time.perf_counter() - t0
            moves, swapped = self.engine.last_moves, True
        elif resolve:   # periodic-cold
            t0 = time.perf_counter()
            if capped:
                assign0 = self._repair_with_demotions(self._assign_at_swap,
                                                      self._active_at_swap)
            else:
                assign0, *_ = repair_assignment(self.sc, self._assign_at_swap,
                                                self._active_at_swap)
            cold = self._new_engine(self.sc)
            assignment = cold.run(assignment=assign0,
                                  max_moves=self.max_moves,
                                  exchange_samples=self.exchange_samples,
                                  finalize=False)
            assoc_s = time.perf_counter() - t0
            self.assignment = np.asarray(assignment)
            moves, swapped = cold.last_moves, True
        else:
            # static policy, and the off-cycle rounds of the re-association
            # policies: minimal feasibility repair, zero descent moves
            if capped:
                self.assignment = self._repair_with_demotions(
                    self.assignment, self._active_prev)
            else:
                self.assignment, *_ = repair_assignment(
                    self.sc, self.assignment, self._active_prev)
        if swapped:
            # swap refs are stored PRE-drain: the next warm re-solve diffs
            # against (and the next cold rebuild repairs from) exactly the
            # state the engines converged on, which is what keeps warm/cold
            # parity bit-identical under capacities
            self._sc_at_swap = self.sc
            self._active_at_swap = self.sc.active_mask.copy()
            self._assign_at_swap = self.assignment.copy()
        if capped:
            # admission tick every round; on swap rounds this is the
            # post-resolve drain (stable loads just freed by the descent)
            admitted_n = self._admission_tick()
            self._round_rejected += self._trim_queue()
        active = self.sc.active_mask
        self._active_prev = active.copy()

        trainer.client_mask = jnp.asarray(self.bridge.client_mask(active))
        newly = (self._admitted & ~admitted_before if capped
                 else delta.arrived)
        arrivals_c = self.bridge.client_mask(newly)
        if arrivals_c.any():
            trainer.readmit_clients(
                jnp.asarray(arrivals_c),
                jnp.asarray(self.bridge.client_assignment(self.assignment)),
                self.sc.n_servers)
        self._record(assoc_s=assoc_s, swapped=swapped, moves=moves,
                     arrived=int(delta.arrived.sum()),
                     departed=int(delta.departed.sum()),
                     admitted=admitted_n)
        return self.bridge.client_assignment(self.assignment)


def run_live(sc: Scenario, ds: FederatedDataset, *,
             policy: str = "incremental-warm", rounds: int = 10,
             resolve_every: int = 1, churn: dict | None = None, seed: int = 0,
             local_iters: int = 5, edge_iters: int = 2, lr: float = 0.05,
             model: str = "mlr", eval_every: int = 1, train_seed: int = 0,
             kind: str = "fast", profile: str = "coarse",
             rel_tol: float = 1e-3, compact: bool | str = "auto",
             shards: int | None = None, ra_backend: str = "xla",
             max_moves: int = 10_000,
             exchange_samples: int = DEFAULT_EXCHANGE_SAMPLES,
             verify: bool = False, overflow_max: int = 64,
             bridge: DeviceClientBridge | None = None) -> LiveHistory:
    """Run one live HFEL co-simulation end-to-end; returns its
    :class:`LiveHistory` (training metrics under ``.train``).

    The association side (``policy``/``resolve_every``/engine knobs) and the
    training side (``local_iters``/``edge_iters``/``lr``/``model``) share
    the scenario through a :func:`device_client_bridge`; churn ticks are
    seeded from ``seed`` and round index only, so different policies at the
    same ``seed`` face the exact same scenario trajectory — the controlled
    comparison the live benchmark and the parity tests rely on.

    ``shards=p`` / ``ra_backend="pallas"`` reach every engine the policies
    build (round-0, periodic-cold rebuilds, the warm engine), so the live
    loop can run the PR-6 sharded sweep; the sharded path keeps the
    bit-identical-assignment contract, hence identical histories.

    ``exchange_samples`` defaults to
    :data:`repro.core.assoc_fast.DEFAULT_EXCHANGE_SAMPLES` (= 64), the SAME
    default as ``FastAssociationEngine.run`` — live runs no longer silently
    drop the Definition-5 escape moves — and is legal under ``shards=p``
    (the sampled-exchange pass is distributed with a bit-identical winner
    merge). Warm/cold swap parity holds with exchanges on: both policies
    descend from the same repaired assignment with the same
    ``PRNGKey(seed)`` stream. Pass 0 for transfer-only descent.

    On a capacitated scenario (``sc.max_devices`` set), arrivals the edges
    cannot admit wait in a FIFO queue bounded by ``overflow_max`` (see
    "Streaming admission under capacities" in the module docstring); the
    per-round queue/admission/rejection counts land in the history's
    ``n_queued`` / ``n_admitted`` / ``n_rejected``.
    """
    runner = LiveHFELRunner(sc, ds.n_clients, policy=policy,
                            resolve_every=resolve_every, churn=churn,
                            seed=seed, kind=kind, profile=profile,
                            rel_tol=rel_tol, compact=compact,
                            shards=shards, ra_backend=ra_backend,
                            max_moves=max_moves,
                            exchange_samples=exchange_samples, verify=verify,
                            overflow_max=overflow_max, bridge=bridge)
    hist = train_federated(ds, method="hfel", n_servers=sc.n_servers,
                           local_iters=local_iters, edge_iters=edge_iters,
                           rounds=rounds, lr=lr, model=model, seed=train_seed,
                           eval_every=eval_every, round_hook=runner)
    runner.history.train = hist
    return runner.history
