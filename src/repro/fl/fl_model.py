"""The paper's federated learning tasks: multinomial logistic regression and
a small MLP (image-classification stand-ins for MNIST/FEMNIST), with masked
full-batch loss/gradients as the paper trains (full batch size)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mlr_init(rng, dim: int, n_classes: int):
    k = jax.random.split(rng, 1)[0]
    return {"w": jax.random.normal(k, (dim, n_classes)) * 0.01,
            "b": jnp.zeros((n_classes,))}


def mlr_logits(params, x):
    return x @ params["w"] + params["b"]


def mlp_init(rng, dim: int, n_classes: int, hidden: int = 128):
    k1, k2 = jax.random.split(rng)
    return {"w1": jax.random.normal(k1, (dim, hidden)) * (dim ** -0.5),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, n_classes)) * (hidden ** -0.5),
            "b2": jnp.zeros((n_classes,))}


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def masked_loss(logits_fn, params, x, y):
    """Full-batch CE; y == -1 marks padding (clients have ragged data)."""
    logits = logits_fn(params, x)
    mask = (y >= 0).astype(jnp.float32)
    y_safe = jnp.maximum(y, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y_safe[:, None], axis=-1)[:, 0]
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def accuracy(logits_fn, params, x, y):
    pred = jnp.argmax(logits_fn(params, x), axis=-1)
    mask = (y >= 0).astype(jnp.float32)
    hits = (pred == y).astype(jnp.float32) * mask
    return jnp.sum(hits) / jnp.maximum(jnp.sum(mask), 1.0)


MODELS = {
    "mlr": (mlr_init, mlr_logits),
    "mlp": (mlp_init, mlp_logits),
}
