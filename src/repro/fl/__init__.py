from repro.fl.fl_model import MODELS, accuracy, masked_loss, mlr_init, mlp_init
from repro.fl.training import FederatedTrainer, TrainHistory, train_federated
from repro.fl.live import (DEFAULT_CHURN, POLICIES, LiveHFELRunner,
                           LiveHistory, run_live)

__all__ = ["MODELS", "accuracy", "masked_loss", "mlr_init", "mlp_init",
           "FederatedTrainer", "TrainHistory", "train_federated",
           "DEFAULT_CHURN", "POLICIES", "LiveHFELRunner", "LiveHistory",
           "run_live"]
