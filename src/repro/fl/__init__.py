from repro.fl.fl_model import MODELS, accuracy, masked_loss, mlr_init, mlp_init
from repro.fl.training import FederatedTrainer, TrainHistory, train_federated

__all__ = ["MODELS", "accuracy", "masked_loss", "mlr_init", "mlp_init",
           "FederatedTrainer", "TrainHistory", "train_federated"]
