"""Federated training loops — paper Algorithm 1 (HFEL) and FedAvg (§V.B).

Everything is vectorized over clients: client parameters live as one pytree
with a leading (n_clients,) axis; local full-batch GD runs as a
``vmap``-of-``scan``; edge aggregation (eq. 8) is a segment-weighted mean
over the device->server assignment; cloud aggregation (eq. 14) a weighted
mean over everything. One jit per round.

The §V.B protocol is preserved: per global round both methods perform the
same TOTAL number of local iterations (L*I); HFEL interleaves I edge
aggregations, FedAvg aggregates only at the cloud.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.federated import FederatedDataset
from repro.fl.fl_model import MODELS, accuracy, masked_loss


def _group_means(leaf, w, assignment, n_servers):
    """eq. (8) weighted group means of a client-stacked leaf — the ONE place
    the group-mean arithmetic (and its zero-weight floor) lives. Returns
    ``(per-client broadcast of its group's mean, per-client group liveness)``;
    the mean is garbage wherever liveness is False (weight-0 group), so
    callers must gate on it."""
    shape1 = (-1,) + (1,) * (leaf.ndim - 1)
    wr = w.reshape(shape1)
    num = jax.ops.segment_sum(leaf * wr, assignment, n_servers)
    den = jax.ops.segment_sum(w, assignment, n_servers)
    server = num / jnp.maximum(den.reshape(shape1), 1e-9)
    return server[assignment], (den > 0)[assignment].reshape(shape1)


@dataclass
class TrainHistory:
    test_acc: list = field(default_factory=list)
    train_acc: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)
    # global-round index of each entry above (evaluation may be subsampled
    # via ``eval_every``; all four lists always share one length)
    eval_rounds: list = field(default_factory=list)

    def as_dict(self):
        return {"test_acc": self.test_acc, "train_acc": self.train_acc,
                "train_loss": self.train_loss,
                "eval_rounds": self.eval_rounds}


class FederatedTrainer:
    """Runs HFEL or FedAvg on a FederatedDataset.

    ``assignment``: (n_clients,) device -> edge-server map (HFEL only) —
    typically the output of the core edge-association algorithm.
    ``client_mask``: boolean participation mask, re-settable between rounds
    (straggler dropping / failure injection hook).
    """

    def __init__(self, ds: FederatedDataset, *, model: str = "mlr",
                 lr: float = 0.01, seed: int = 0):
        self.ds = ds
        init_fn, self.logits_fn = MODELS[model]
        rng = jax.random.key(seed)
        proto = init_fn(rng, ds.dim, ds.n_classes)
        # identical init across clients (the paper broadcasts omega^0)
        self.client_params = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (ds.n_clients,) + p.shape), proto)
        self.lr = lr
        self.sizes = jnp.asarray(ds.client_sizes)
        self.x = jnp.asarray(ds.client_x)
        self.y = jnp.asarray(ds.client_y)
        self.client_mask = jnp.ones((ds.n_clients,), bool)

        loss = partial(masked_loss, self.logits_fn)

        def local_steps(params, x, y, n_steps):
            def step(p, _):
                g = jax.grad(loss)(p, x, y)
                return jax.tree.map(lambda a, b: a - lr * b, p, g), None

            out, _ = jax.lax.scan(step, params, None, length=n_steps)
            return out

        self._local = jax.jit(jax.vmap(local_steps, in_axes=(0, 0, 0, None)),
                              static_argnums=3)

    # -- aggregation ---------------------------------------------------------

    def _weights(self):
        return self.sizes * self.client_mask.astype(self.sizes.dtype)

    def edge_aggregate(self, assignment: jnp.ndarray, n_servers: int):
        """eq. (8): weighted mean within each server group, broadcast back.

        A group whose participating weight is zero (every member masked out
        — e.g. a fully-departed edge server under churn) has no defined
        mean: its clients KEEP their current parameters instead of receiving
        the degenerate ``0 / max(den, eps)`` quotient, which would silently
        zero a parked client's state and poison its later re-admission.
        Masked clients of a live group still receive the group broadcast
        (re-sync on return), matching the cloud semantics below.
        """
        w = self._weights()
        assignment = jnp.asarray(assignment)

        def agg(leaf):
            mean, live = _group_means(leaf, w, assignment, n_servers)
            return jnp.where(live, mean, leaf)

        self.client_params = jax.tree.map(agg, self.client_params)

    def cloud_aggregate(self):
        """eq. (14): global weighted mean, broadcast back (to masked clients
        too — stragglers re-sync from the global model). With NO
        participating client at all there is no mean; everyone keeps their
        parameters rather than collapsing to the zero quotient."""
        w = self._weights()

        def agg(leaf):
            wr = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
            mean = jnp.sum(leaf * wr, axis=0) / jnp.maximum(jnp.sum(w), 1e-9)
            return jnp.where(jnp.sum(w) > 0,
                             jnp.broadcast_to(mean, leaf.shape), leaf)

        self.client_params = jax.tree.map(agg, self.client_params)

    def readmit_clients(self, arrivals: jnp.ndarray, assignment: jnp.ndarray,
                        n_servers: int):
        """Re-admit arriving clients with their edge's CURRENT parameters:
        each arrival's state is set to the eq.-(8) weighted mean of its
        assigned server's participating members (the arrivals themselves
        excluded as donors), falling back to the global weighted mean when
        that group is otherwise empty — and keeping the arrival's old
        parameters when nobody at all can donate. This is the trainer-side
        half of an elastic hot-swap: a device that returns mid-training
        joins its edge where the edge *is*, not where the device left off.
        """
        arrivals = jnp.asarray(arrivals, bool)
        assignment = jnp.asarray(assignment)
        donors = self.client_mask & ~arrivals
        w = self.sizes * donors.astype(self.sizes.dtype)

        def agg(leaf):
            shape1 = (-1,) + (1,) * (leaf.ndim - 1)
            mean, grp_live = _group_means(leaf, w, assignment, n_servers)
            gmean = jnp.broadcast_to(
                jnp.sum(leaf * w.reshape(shape1), axis=0)
                / jnp.maximum(jnp.sum(w), 1e-9), leaf.shape)
            src = jnp.where(grp_live, mean, gmean)
            take = arrivals.reshape(shape1) & (jnp.sum(w) > 0)
            return jnp.where(take, src, leaf)

        self.client_params = jax.tree.map(agg, self.client_params)

    def global_params(self):
        return jax.tree.map(lambda p: p[0], self.client_params)

    # -- rounds ---------------------------------------------------------------

    def hfel_round(self, assignment, n_servers: int, local_iters: int,
                   edge_iters: int):
        for _ in range(edge_iters):
            self.client_params = self._local(self.client_params, self.x,
                                             self.y, local_iters)
            self.edge_aggregate(assignment, n_servers)
        self.cloud_aggregate()

    def fedavg_round(self, local_iters: int, edge_iters: int):
        """Same local work (L*I), single cloud aggregation (McMahan et al.)."""
        self.client_params = self._local(self.client_params, self.x, self.y,
                                         local_iters * edge_iters)
        self.cloud_aggregate()

    # -- metrics ---------------------------------------------------------------

    def evaluate(self) -> dict:
        g = self.global_params()
        test_acc = accuracy(self.logits_fn, g, jnp.asarray(self.ds.test_x),
                            jnp.asarray(self.ds.test_y))
        flat_x = self.x.reshape(-1, self.ds.dim)
        flat_y = self.y.reshape(-1)
        train_acc = accuracy(self.logits_fn, g, flat_x, flat_y)
        train_loss = masked_loss(self.logits_fn, g, flat_x, flat_y)
        return {"test_acc": float(test_acc), "train_acc": float(train_acc),
                "train_loss": float(train_loss)}


def train_federated(ds: FederatedDataset, *, method: str = "hfel",
                    assignment=None, n_servers: int = 5,
                    local_iters: int = 10, edge_iters: int = 5,
                    rounds: int = 50, lr: float = 0.01, model: str = "mlr",
                    seed: int = 0, eval_every: int = 1,
                    round_hook: Callable | None = None) -> TrainHistory:
    """Run ``rounds`` global iterations of HFEL or FedAvg; returns history.

    ``round_hook`` runs before each round and is either

    * a plain callable ``hook(trainer, round_idx)`` (failure injection /
      straggler masking — the historical surface), or
    * a *round policy* object exposing
      ``begin_round(trainer, round_idx) -> assignment | None``: returning an
      (n_clients,) array hot-swaps the HFEL edge assignment for this round
      and every following one until the next swap. Swaps land between cloud
      aggregations (before the round's first local step), where the global
      weighted mean is invariant to the grouping — see
      :class:`repro.fl.live.LiveHFELRunner` for the live co-simulation
      policy built on this.
    """
    trainer = FederatedTrainer(ds, model=model, lr=lr, seed=seed)
    if assignment is None:
        assignment = np.arange(ds.n_clients) % n_servers
    assignment = jnp.asarray(assignment)
    hist = TrainHistory()
    begin_round = getattr(round_hook, "begin_round", None)
    for r in range(rounds):
        if begin_round is not None:
            swapped = begin_round(trainer, r)
            if swapped is not None:
                assignment = jnp.asarray(swapped)
        elif round_hook is not None:
            round_hook(trainer, r)
        if method == "hfel":
            trainer.hfel_round(assignment, n_servers, local_iters, edge_iters)
        elif method == "fedavg":
            trainer.fedavg_round(local_iters, edge_iters)
        else:
            raise ValueError(method)
        if r % eval_every == 0 or r == rounds - 1:
            m = trainer.evaluate()
            hist.test_acc.append(m["test_acc"])
            hist.train_acc.append(m["train_acc"])
            hist.train_loss.append(m["train_loss"])
            hist.eval_rounds.append(r)
    return hist
