#!/usr/bin/env python
"""hfellint driver: run the repo's JAX-aware static-analysis pass.

    python scripts/lint.py --check            # the tier-1 gate (default)
    python scripts/lint.py --fix-baseline     # re-record current findings
    python scripts/lint.py --check src/repro/core   # subset of targets

``--check`` lints the targets (default: src/repro, benchmarks, scripts,
examples), diffs the findings against ``lint_baseline.json`` at the repo
root, and exits non-zero if anything NEW appears. Baselined findings must
carry an inline ``# hfellint: disable=RULE -- reason`` pragma or a baseline
entry; ``--fix-baseline`` regenerates the latter from the current state
(dropping entries for fixed violations). Stale baseline entries are reported
but never fail the gate.

Stdlib-only on purpose (no jax import): this runs unconditionally at the
top of scripts/tier1.sh.
"""

from __future__ import annotations

import argparse
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import (diff_against_baseline, lint_paths,  # noqa: E402
                            load_baseline, save_baseline)
from repro.analysis.baseline import DEFAULT_BASELINE  # noqa: E402

DEFAULT_TARGETS = ["src/repro", "benchmarks", "scripts", "examples"]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--check", action="store_true",
                      help="fail on findings not in the baseline (default)")
    mode.add_argument("--fix-baseline", action="store_true",
                      help="regenerate the baseline from current findings")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO_ROOT, DEFAULT_BASELINE),
                    help="baseline JSON path (default: repo root)")
    ap.add_argument("targets", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_TARGETS})")
    args = ap.parse_args(argv)

    targets = args.targets or DEFAULT_TARGETS
    findings = lint_paths(targets, root=REPO_ROOT)

    if args.fix_baseline:
        body = save_baseline(args.baseline, findings)
        print(f"lint: baseline rewritten with "
              f"{sum(e['count'] for e in body['findings'].values())} "
              f"finding(s) across {len(body['findings'])} fingerprint(s) "
              f"-> {os.path.relpath(args.baseline, REPO_ROOT)}")
        return 0

    baseline = load_baseline(args.baseline)
    new, stale = diff_against_baseline(findings, baseline)
    for entry in stale:
        print(f"lint: stale baseline entry {entry['fingerprint']} "
              f"({entry['rule']} {entry['path']}: {entry['line']!r}) — "
              "fixed? run --fix-baseline to drop it")
    baselined = len(findings) - len(new)
    if new:
        for f in new:
            print(f.render())
        print(f"lint: FAIL — {len(new)} new finding(s) "
              f"({baselined} baselined, {len(stale)} stale)")
        return 1
    print(f"lint: OK — 0 new findings "
          f"({baselined} baselined, {len(stale)} stale)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
