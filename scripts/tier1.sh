#!/usr/bin/env bash
# Tier-1 verification: run the FULL test suite. The seed_known_failure set
# (tests/conftest.py) is empty since PR 3 fixed the 14 seed-snapshot jax
# incompatibilities, so the marker filter below currently deselects nothing;
# it stays as plumbing for any future environment-bound straggler. Extra
# pytest arguments pass through, e.g. `scripts/tier1.sh tests/test_assoc_fast.py`.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not seed_known_failure" "$@"
