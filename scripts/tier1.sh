#!/usr/bin/env bash
# Tier-1 verification: run the FULL test suite. The seed_known_failure set
# (tests/conftest.py) is empty since PR 3 fixed the 14 seed-snapshot jax
# incompatibilities, so that marker filter currently deselects nothing; it
# stays as plumbing for any future environment-bound straggler.
#
#   scripts/tier1.sh            full tier-1 suite (the PR gate)
#   scripts/tier1.sh --fast     developer loop: deselect the `slow`-marked
#                               multi-minute association/launch tests
#
# Extra pytest arguments pass through, e.g.
# `scripts/tier1.sh tests/test_assoc_fast.py`.
set -euo pipefail
cd "$(dirname "$0")/.."
MARKER="not seed_known_failure"
if [[ "${1:-}" == "--fast" ]]; then
    MARKER="$MARKER and not slow"
    shift
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "$MARKER" "$@"
