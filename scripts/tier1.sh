#!/usr/bin/env bash
# Tier-1 verification with a meaningful green/red signal: run the full test
# suite minus the seed_known_failure set (tests already broken in the seed
# snapshot — see SEED_KNOWN_FAILURES in tests/conftest.py). Extra pytest
# arguments pass through, e.g. `scripts/tier1.sh tests/test_assoc_fast.py`.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not seed_known_failure" "$@"
