#!/usr/bin/env bash
# Tier-1 verification: run the FULL test suite. The seed_known_failure set
# (tests/conftest.py) is empty since PR 3 fixed the 14 seed-snapshot jax
# incompatibilities, so that marker filter currently deselects nothing; it
# stays as plumbing for any future environment-bound straggler.
#
#   scripts/tier1.sh            full tier-1 suite (the PR gate)
#   scripts/tier1.sh --fast     developer loop: deselect the `slow`-marked
#                               multi-minute association/launch tests
#
# Marker hygiene (tests/_marker_hygiene.py): tier-1 exports
# TIER1_SLOW_MARKER_LIMIT_S (default 30) so any unmarked test that crosses
# the limit FAILS — the fast tier stays fast as the suite grows. Unknown
# markers fail collection via --strict-markers, and --durations prints the
# slowest tests so creep is visible before it crosses the limit. Override
# the limit (or disable with 0) by exporting the variable yourself.
#
# Extra pytest arguments pass through, e.g.
# `scripts/tier1.sh tests/test_assoc_fast.py`.
set -euo pipefail
cd "$(dirname "$0")/.."
MARKER="not seed_known_failure"
if [[ "${1:-}" == "--fast" ]]; then
    MARKER="$MARKER and not slow"
    shift
fi
export TIER1_SLOW_MARKER_LIMIT_S="${TIER1_SLOW_MARKER_LIMIT_S:-30}"
# hfellint gate: the static-analysis pass (scripts/lint.py, rules in
# src/repro/analysis/rules.py) must report zero findings beyond
# lint_baseline.json before any tests run — in --fast mode too. It is
# jax-free and finishes in ~2s; see experiments/lint_rules.md.
python scripts/lint.py --check
# Pin a fixed host-device count so the shard_map sweep tests
# (tests/test_assoc_sharded.py) see a deterministic 4-device mesh on this
# CPU container; must be set before jax first imports.
export XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "$MARKER" --strict-markers --durations=15 "$@"
