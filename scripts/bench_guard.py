#!/usr/bin/env python
"""Guard against benchmark timing regressions.

``benchmarks/run.py`` rotates the previous results of every section it
refreshes into ``experiments/bench_results.prev.json`` (per-section, so a
``--only`` run never disturbs other sections' baselines). This script diffs
the ``timings`` dicts of every section present in the two files, prints a
per-key speedup table, and fails (exit 1) when any timing shared by both
files regressed by more than ``--max-ratio`` (default 2x).

Sections or keys present in only one of current/previous are informational:
newly added benchmarks must not fail the guard, and retired ones are only
reported as removed. Keys listed in ``EXPECTED_NEW_SUBSTRINGS`` (e.g. the
bucketed adaptive-slot-width sweep points added in PR 3) are additionally
labelled as expected, so a first run after adding a benchmark reads as
intentional one-sided tolerance rather than an anonymous diff.

Usage:
    python benchmarks/run.py --only assoc_scale
    python scripts/bench_guard.py            # compares current vs previous
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# Timing keys that are legitimately one-sided on their first comparison:
# benchmarks added by the bucketed (adaptive slot width) sweep, by the
# churn (incremental re-convergence) regime, by the live co-simulation
# section (elastic re-association during training — anchored to its section
# prefix so unrelated keys merely containing "live" are still flagged), and
# by the sharded-sweep + golden-section kernel scaling points, by the
# capacitated streaming-admission section (bulk + per-arrival placement
# rates at the N=20k stress geometry), and by the distributed-exchange
# points (PR 10: sampled exchanges under sharding, plus the N=50k sharded
# live round — "sharded_live" keys).
# Matched by substring against "section/key" names.
EXPECTED_NEW_SUBSTRINGS = ("bucketed", "churn", "live_hfel/", "golden",
                           "sharded", "admission", "exchange",
                           "sharded_live")


def load_timings(path: str) -> tuple[dict[str, float],
                                     dict[str, int]] | None:
    """Flatten every section's ``timings`` dict to {"section/key": seconds},
    plus the matching device counts {"section/key": n} for keys a section
    declares in its ``device_counts`` dict (the sharded assoc_scale points).

    Returns None when the file is missing/unreadable, ({}, {}) when it
    holds no timing-bearing sections.
    """
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            data = json.load(f)
        out: dict[str, float] = {}
        devs: dict[str, int] = {}
        for section, body in data.items():
            timings = body.get("timings") if isinstance(body, dict) else None
            if not isinstance(timings, dict):
                continue
            counts = body.get("device_counts")
            counts = counts if isinstance(counts, dict) else {}
            for key, value in timings.items():
                out[f"{section}/{key}"] = float(value)
                if key in counts:
                    devs[f"{section}/{key}"] = int(counts[key])
        return out, devs
    except (OSError, ValueError, TypeError) as e:
        print(f"bench_guard: unreadable results file {path} ({e})")
        return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="experiments/bench_results.json")
    ap.add_argument("--baseline", default="experiments/bench_results.prev.json")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when current > ratio * baseline")
    args = ap.parse_args()

    loaded = load_timings(args.current)
    if loaded is None:
        print(f"bench_guard: no current results at {args.current} "
              "(run `python benchmarks/run.py --only assoc_scale` first)")
        return 1
    cur, cur_devs = loaded
    if not cur:
        print("bench_guard: current results carry no timings")
        return 1
    loaded = load_timings(args.baseline)
    base, base_devs = loaded if loaded is not None else ({}, {})
    if not base:
        print(f"bench_guard: no baseline at {args.baseline}; nothing to "
              "compare (first run passes trivially)")
        return 0

    shared = sorted(set(base) & set(cur))
    regressions = []
    if shared:
        width = max(len(name) for name in shared)
        header = (f"{'benchmark':<{width}}  {'baseline':>10}  "
                  f"{'current':>10}  {'speedup':>8}")
        print(header)
        print("-" * len(header))
        for name in shared:
            # a sharded timing taken at a different device count is a
            # different experiment, not a regression — report, never fail
            nd_cur = cur_devs.get(name)
            nd_base = base_devs.get(name)
            if (nd_cur or nd_base) and nd_cur != nd_base:
                print(f"{name:<{width}}  devices {nd_base} -> {nd_cur}: "
                      "incomparable, skipped")
                continue
            speedup = base[name] / max(cur[name], 1e-12)
            ratio = cur[name] / max(base[name], 1e-12)
            flag = "  <-- REGRESSION" if ratio > args.max_ratio else ""
            print(f"{name:<{width}}  {base[name]:>9.3f}s  {cur[name]:>9.3f}s"
                  f"  {speedup:>7.2f}x{flag}")
            if ratio > args.max_ratio:
                regressions.append(name)
    only_new = sorted(set(cur) - set(base))
    expected = [n for n in only_new
                if any(s in n for s in EXPECTED_NEW_SUBSTRINGS)]
    only_new = [n for n in only_new if n not in expected]
    if expected:
        print("expected new timings (one-sided on first run): "
              + ", ".join(expected))
    if only_new:
        print("new timings (no baseline): " + ", ".join(only_new))
    only_old = sorted(set(base) - set(cur))
    if only_old:
        print("removed timings (baseline only): " + ", ".join(only_old))
    if not shared:
        print("bench_guard: no overlapping timings; nothing to compare")

    if regressions:
        print(f"bench_guard: FAIL — {len(regressions)} timing(s) regressed "
              f">{args.max_ratio}x: {', '.join(regressions)}")
        return 1
    print("bench_guard: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
