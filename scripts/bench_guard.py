#!/usr/bin/env python
"""Guard against association-benchmark timing regressions.

``benchmarks/run.py`` rotates the previous ``experiments/bench_results.json``
to ``experiments/bench_results.prev.json`` before writing fresh results.
This script diffs the ``assoc_scale`` timings of the two files and fails
(exit 1) when any timing regressed by more than ``--max-ratio`` (default 2x).

Usage:
    python benchmarks/run.py --only assoc_scale
    python scripts/bench_guard.py            # compares current vs previous
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def load_timings(path: str) -> dict[str, float] | None:
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            data = json.load(f)
        section = data.get("assoc_scale") or {}
        timings = section.get("timings") or {}
        return {k: float(v) for k, v in timings.items()}
    except (OSError, ValueError, TypeError) as e:
        print(f"bench_guard: unreadable results file {path} ({e})")
        return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="experiments/bench_results.json")
    ap.add_argument("--baseline", default="experiments/bench_results.prev.json")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when current > ratio * baseline")
    args = ap.parse_args()

    cur = load_timings(args.current)
    if cur is None:
        print(f"bench_guard: no current results at {args.current} "
              "(run `python benchmarks/run.py --only assoc_scale` first)")
        return 1
    if not cur:
        print("bench_guard: current results carry no assoc_scale timings")
        return 1
    base = load_timings(args.baseline)
    if not base:
        print(f"bench_guard: no baseline at {args.baseline}; nothing to "
              "compare (first run passes trivially)")
        return 0

    regressions = []
    for name in sorted(set(base) & set(cur)):
        ratio = cur[name] / max(base[name], 1e-12)
        flag = " <-- REGRESSION" if ratio > args.max_ratio else ""
        print(f"{name}: {base[name]:.3f}s -> {cur[name]:.3f}s "
              f"({ratio:.2f}x){flag}")
        if ratio > args.max_ratio:
            regressions.append(name)
    only_new = sorted(set(cur) - set(base))
    if only_new:
        print("new timings (no baseline): " + ", ".join(only_new))

    if regressions:
        print(f"bench_guard: FAIL — {len(regressions)} timing(s) regressed "
              f">{args.max_ratio}x: {', '.join(regressions)}")
        return 1
    print("bench_guard: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
