"""Recompilation sentinel: the dynamic half of the hfellint pass.

The static rules (tests/test_lint.py) keep the jitted code cache-friendly;
these tests assert the caches actually HIT, by counting real XLA compile
events (``compile_log`` fixture over ``jax_log_compiles``) around
FastAssociationEngine solve cycles.

Compile budget (documented contract):

* one cold-run -> churn -> warm-rerun cycle compiles ``_run_device`` at
  most TWICE per sweep space — the cold-init variant and the warm-init
  (toggle-cache-carrying) variant; every other compile in the cycle is
  one-off eager-op warm-up, not per-cycle work;
* an IDENTICAL repeat cycle (fresh engine, same scenario seed, same
  statics) compiles NOTHING — zero events — because ``_run_device``'s jit
  cache is module-global (PR-3) and keyed on shapes + static config only;
* the sharded engine's repeat solve likewise compiles nothing thanks to the
  PR-6 ``_SHARDED_CACHE`` keyed on (mesh, bucket shapes, statics); bypassing
  that cache is OBSERVABLE — the sentinel records fresh compiles — which is
  exactly the regression this tier exists to catch.
"""

import numpy as np
import pytest

import repro.core.assoc_fast as assoc_fast
from repro.core.assoc_fast import FastAssociationEngine
from repro.core.scenario import make_large_scenario, perturb_scenario

N, K = 16, 3
CHURN = dict(drift_m=60.0, move_frac=0.1, flip_frac=0.05, depart_frac=0.05)
#: max ``_run_device`` compilations in one cold->churn->warm cycle:
#: the cold-init variant + the warm-init variant
RUN_DEVICE_BUDGET = 2


def _cycle(compact, shards=None, cap_slack=None,
           exchange_samples=0) -> np.ndarray:
    """cold run -> one churn tick -> warm incremental rerun; returns the
    warm stable point. Deterministic: fixed seeds (the sampled-exchange
    stream is itself seed-derived, so exchange_samples>0 stays bitwise
    repeatable)."""
    sc = make_large_scenario(N, K, seed=0, cap_slack=cap_slack)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                rel_tol=1e-3, compact=compact, shards=shards)
    eng.run("nearest", max_moves=3, exchange_samples=exchange_samples,
            finalize=False)
    sc2, delta = perturb_scenario(sc, seed=1, **CHURN)
    return np.asarray(eng.rerun_incremental(
        sc2, delta, max_moves=3, exchange_samples=exchange_samples,
        finalize=False))


@pytest.mark.parametrize("compact", [False, True, "bucketed"],
                         ids=["dense", "flat", "bucketed"])
def test_cycle_compile_budget_and_global_jit_cache(compile_log, compact):
    compile_log.reset()
    first = _cycle(compact)
    n_run_device = compile_log.count("_run_device")
    assert n_run_device <= RUN_DEVICE_BUDGET, (
        f"{compact!r} cycle compiled _run_device {n_run_device}x "
        f"(budget {RUN_DEVICE_BUDGET}: cold-init + warm-init variants) — "
        "a static config leaked into the traced signature")
    # the identical repeat cycle must be compile-FREE: _run_device's jit
    # cache is module-global, so a fresh engine on the same-shaped scenario
    # reuses every program (and every eager op is already warm)
    compile_log.reset()
    second = _cycle(compact)
    assert compile_log.events == [], (
        f"repeat {compact!r} cycle recompiled {compile_log.events} — the "
        "module-global jit cache missed on identical shapes/statics")
    np.testing.assert_array_equal(first, second)


@pytest.mark.parametrize("compact", [False, True, "bucketed"],
                         ids=["dense", "flat", "bucketed"])
def test_capacity_mask_adds_no_run_device_compiles(compile_log, compact):
    """Per-edge ``max_devices`` caps enter ``_run_device`` as a TRACED
    ``(K,)`` array (uncapped engines pass a never-binding filled array), so
    flipping capacities on must not grow the traced signature: once the
    uncapped programs are warm, a capacitated cycle on the same shapes
    compiles ZERO new ``_run_device`` variants."""
    _cycle(compact)                      # warm the uncapped programs
    compile_log.reset()
    _cycle(compact, cap_slack=1.3)       # binding caps, same shapes/statics
    n = compile_log.count("_run_device")
    assert n == 0, (
        f"capacitated {compact!r} cycle compiled _run_device {n}x on warm "
        "same-shape caches — the capacity gate leaked a static into the "
        "traced signature")


def test_sharded_runner_cache_hits_and_bypass_is_caught(compile_log,
                                                        monkeypatch):
    """The PR-6 contract: repeat same-shape sharded solves reuse the
    ``_SHARDED_CACHE`` program (zero compiles); wiping the cache forces
    jit(shard_map(...)) to rebuild, and the sentinel SEES it."""
    first = _cycle("bucketed", shards=1)     # may compile (cold)
    compile_log.reset()
    second = _cycle("bucketed", shards=1)
    assert compile_log.events == [], (
        f"repeat sharded cycle recompiled {compile_log.events} — "
        "_SHARDED_CACHE missed on an identical (mesh, shapes, statics) key")
    np.testing.assert_array_equal(first, second)

    monkeypatch.setattr(assoc_fast, "_SHARDED_CACHE", {})
    compile_log.reset()
    third = _cycle("bucketed", shards=1)
    assert len(compile_log.events) > 0, (
        "bypassing _SHARDED_CACHE produced no compile events — the "
        "recompilation sentinel lost its signal")
    np.testing.assert_array_equal(first, third)


def test_sharded_exchange_cycle_compile_budget_and_cache_key(compile_log):
    """PR 10 lifts the sharded exchange_samples=0 restriction; the compile
    contract extends with it: ``exchange_samples`` is ONE static on the
    sharded program, so after the no-exchange programs are warm a sharded
    exchange cycle compiles at most the cold-init + warm-init variants of
    the new static, an IDENTICAL repeat compiles nothing, and the
    ``_SHARDED_CACHE`` key carries the exchange static explicitly (distinct
    budgets must never collide on one compiled program)."""
    _cycle("bucketed", shards=1)            # warm the no-exchange programs
    compile_log.reset()
    first = _cycle("bucketed", shards=1, exchange_samples=8)
    n = len(compile_log.events)
    assert n <= RUN_DEVICE_BUDGET, (
        f"sharded exchange cycle compiled {n} programs on warm no-exchange "
        f"caches (budget {RUN_DEVICE_BUDGET}: cold-init + warm-init variants "
        "of the exchange_samples=8 static) — something besides the exchange "
        "static leaked into the traced signature")
    compile_log.reset()
    second = _cycle("bucketed", shards=1, exchange_samples=8)
    assert compile_log.events == [], (
        f"repeat sharded exchange cycle recompiled {compile_log.events} — "
        "_SHARDED_CACHE missed on an identical key with exchanges on")
    np.testing.assert_array_equal(first, second)
    # the cache key includes the exchange static (position pinned by
    # _sharded_runner): both the 0- and 8-sample programs are resident
    budgets = {key[-2] for key in assoc_fast._SHARDED_CACHE}
    assert {0, 8} <= budgets, (
        f"_SHARDED_CACHE keys carry exchange budgets {budgets} — expected "
        "distinct entries for exchange_samples=0 and =8")
