"""scripts/bench_guard.py gates every PR's benchmark timings — cover its
comparison semantics: one-sided sections/keys, expected-new labelling, the
>max-ratio failure path, and the missing-file edge cases."""

import importlib.util
import json
import pathlib
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parents[1]
_spec = importlib.util.spec_from_file_location(
    "bench_guard", _ROOT / "scripts" / "bench_guard.py")
bench_guard = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_guard)


def _write(path, sections):
    path.write_text(json.dumps(sections))
    return str(path)


def _run(monkeypatch, tmp_path, current, baseline, *extra):
    argv = ["bench_guard.py"]
    if current is not None:
        argv += ["--current", _write(tmp_path / "cur.json", current)]
    else:
        argv += ["--current", str(tmp_path / "missing_cur.json")]
    if baseline is not None:
        argv += ["--baseline", _write(tmp_path / "prev.json", baseline)]
    else:
        argv += ["--baseline", str(tmp_path / "missing_prev.json")]
    monkeypatch.setattr(sys, "argv", argv + list(extra))
    return bench_guard.main()


def test_load_timings_flattens_sections(tmp_path):
    path = _write(tmp_path / "r.json", {
        "assoc_scale": {"timings": {"a": 1.5, "b": 2.0}, "other": "x",
                        "device_counts": {"b": 4, "absent_key": 2}},
        "no_timings_section": {"cost": 3.0},
        "scalar_section": 7,
    })
    timings, devs = bench_guard.load_timings(path)
    assert timings == {"assoc_scale/a": 1.5, "assoc_scale/b": 2.0}
    # device counts attach only to keys that actually carry a timing
    assert devs == {"assoc_scale/b": 4}
    assert bench_guard.load_timings(str(tmp_path / "nope.json")) is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert bench_guard.load_timings(str(bad)) is None


def test_device_count_mismatch_skips_comparison(monkeypatch, tmp_path,
                                                capsys):
    """A sharded timing re-measured at a different device count is a
    different experiment: never compared, never a regression."""
    rc = _run(monkeypatch, tmp_path,
              {"assoc_scale": {"timings": {"sharded_cold": 9.0},
                               "device_counts": {"sharded_cold": 2}}},
              {"assoc_scale": {"timings": {"sharded_cold": 1.0},
                               "device_counts": {"sharded_cold": 4}}})
    out = capsys.readouterr().out
    assert rc == 0
    assert "devices 4 -> 2: incomparable, skipped" in out
    assert "REGRESSION" not in out
    # same device count on both sides compares (and fails) normally
    rc = _run(monkeypatch, tmp_path,
              {"assoc_scale": {"timings": {"sharded_cold": 9.0},
                               "device_counts": {"sharded_cold": 4}}},
              {"assoc_scale": {"timings": {"sharded_cold": 1.0},
                               "device_counts": {"sharded_cold": 4}}})
    assert rc == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_sharded_and_golden_keys_expected_new(monkeypatch, tmp_path, capsys):
    rc = _run(monkeypatch, tmp_path,
              {"assoc_scale": {"timings": {"shared": 1.0,
                                           "sharded_cold_n50000": 500.0}},
               "kernels": {"timings": {"golden_default_g64_xla_us": 9.0}}},
              {"assoc_scale": {"timings": {"shared": 1.0}}})
    out = capsys.readouterr().out
    assert rc == 0
    expected_line = [l for l in out.splitlines()
                     if l.startswith("expected new timings")]
    assert len(expected_line) == 1
    assert "sharded_cold_n50000" in expected_line[0]
    assert "golden_default_g64_xla_us" in expected_line[0]


def test_ok_within_ratio(monkeypatch, tmp_path, capsys):
    rc = _run(monkeypatch, tmp_path,
              {"s": {"timings": {"k": 1.9}}},
              {"s": {"timings": {"k": 1.0}}})
    out = capsys.readouterr().out
    assert rc == 0 and "bench_guard: OK" in out
    assert "REGRESSION" not in out


def test_regression_over_2x_fails(monkeypatch, tmp_path, capsys):
    rc = _run(monkeypatch, tmp_path,
              {"s": {"timings": {"k": 2.5, "fine": 1.0}}},
              {"s": {"timings": {"k": 1.0, "fine": 1.0}}})
    out = capsys.readouterr().out
    assert rc == 1
    assert "REGRESSION" in out and "s/k" in out
    assert "FAIL" in out and "1 timing(s) regressed" in out


def test_max_ratio_override(monkeypatch, tmp_path):
    cur = {"s": {"timings": {"k": 2.5}}}
    base = {"s": {"timings": {"k": 1.0}}}
    assert _run(monkeypatch, tmp_path, cur, base, "--max-ratio", "3.0") == 0
    assert _run(monkeypatch, tmp_path, cur, base, "--max-ratio", "1.5") == 1


def test_one_sided_sections_and_keys_are_informational(monkeypatch, tmp_path,
                                                       capsys):
    """Newly added benchmarks must not fail the guard; retired ones are only
    reported as removed."""
    rc = _run(monkeypatch, tmp_path,
              {"s": {"timings": {"shared": 1.0, "brand_new": 9.0}},
               "new_section": {"timings": {"x": 50.0}}},
              {"s": {"timings": {"shared": 1.0, "retired": 0.1}}})
    out = capsys.readouterr().out
    assert rc == 0
    assert "new timings (no baseline): new_section/x, s/brand_new" in out
    assert "removed timings (baseline only): s/retired" in out


def test_expected_new_substrings_labelled(monkeypatch, tmp_path, capsys):
    """Keys from the bucketed and churn benchmarks read as intentional
    one-sided tolerance on their first run, not anonymous diffs."""
    rc = _run(monkeypatch, tmp_path,
              {"assoc_scale": {"timings": {"shared": 1.0,
                                           "bucketed_permove": 0.5,
                                           "churn_warm_n1000_k20": 30.0,
                                           "misc_new": 2.0}}},
              {"assoc_scale": {"timings": {"shared": 1.0}}})
    out = capsys.readouterr().out
    assert rc == 0
    expected_line = [l for l in out.splitlines()
                     if l.startswith("expected new timings")]
    assert len(expected_line) == 1
    assert "bucketed_permove" in expected_line[0]
    assert "churn_warm_n1000_k20" in expected_line[0]
    assert "misc_new" not in expected_line[0]
    assert "new timings (no baseline): assoc_scale/misc_new" in out


def test_live_hfel_section_keys_expected_new(monkeypatch, tmp_path, capsys):
    """The live co-simulation section's timing keys (all carrying "live")
    read as intentional one-sided tolerance on their first run, and a
    shared live key still regresses like any other timing."""
    rc = _run(monkeypatch, tmp_path,
              {"live_hfel": {"timings": {"live_assoc_warm_n250_k10": 4.0,
                                         "live_assoc_cold_n250_k10": 9.0}},
               "assoc_scale": {"timings": {"shared": 1.0,
                                           "liveness_probe": 2.0}}},
              {"assoc_scale": {"timings": {"shared": 1.0}}})
    out = capsys.readouterr().out
    assert rc == 0
    expected_line = [l for l in out.splitlines()
                     if l.startswith("expected new timings")]
    assert len(expected_line) == 1
    assert "live_hfel/live_assoc_warm_n250_k10" in expected_line[0]
    assert "live_hfel/live_assoc_cold_n250_k10" in expected_line[0]
    # "live" alone must NOT exempt keys outside the live_hfel section
    assert "liveness_probe" not in expected_line[0]
    assert "new timings (no baseline): assoc_scale/liveness_probe" in out
    # once baselined, a live timing regression fails the guard
    rc = _run(monkeypatch, tmp_path,
              {"live_hfel": {"timings": {"live_assoc_warm_n250_k10": 9.0}}},
              {"live_hfel": {"timings": {"live_assoc_warm_n250_k10": 4.0}}})
    assert rc == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_exchange_and_sharded_live_keys_expected_new(monkeypatch, tmp_path,
                                                     capsys):
    """The PR-10 distributed-exchange timings — sharded exchange parity
    probes in assoc_scale and the N=50k sharded live round — read as
    intentional one-sided tolerance on their first comparison."""
    rc = _run(monkeypatch, tmp_path,
              {"assoc_scale": {"timings": {"shared": 1.0,
                                           "exchange_parity_n2000_k40": 6.0}},
               "live_hfel": {"timings": {
                   "sharded_live_warm_n50000_k500": 400.0},
                   "device_counts": {
                       "sharded_live_warm_n50000_k500": 4}}},
              {"assoc_scale": {"timings": {"shared": 1.0}}})
    out = capsys.readouterr().out
    assert rc == 0
    expected_line = [l for l in out.splitlines()
                     if l.startswith("expected new timings")]
    assert len(expected_line) == 1
    assert "exchange_parity_n2000_k40" in expected_line[0]
    assert "sharded_live_warm_n50000_k500" in expected_line[0]
    # re-measured at a different device count: incomparable, never compared
    rc = _run(monkeypatch, tmp_path,
              {"live_hfel": {"timings": {
                  "sharded_live_warm_n50000_k500": 900.0},
                  "device_counts": {"sharded_live_warm_n50000_k500": 2}}},
              {"live_hfel": {"timings": {
                  "sharded_live_warm_n50000_k500": 400.0},
                  "device_counts": {"sharded_live_warm_n50000_k500": 4}}})
    out = capsys.readouterr().out
    assert rc == 0 and "incomparable, skipped" in out


def test_missing_current_fails(monkeypatch, tmp_path, capsys):
    rc = _run(monkeypatch, tmp_path, None, {"s": {"timings": {"k": 1.0}}})
    assert rc == 1
    assert "no current results" in capsys.readouterr().out


def test_empty_current_timings_fails(monkeypatch, tmp_path, capsys):
    rc = _run(monkeypatch, tmp_path, {"s": {"cost": 1.0}},
              {"s": {"timings": {"k": 1.0}}})
    assert rc == 1
    assert "no timings" in capsys.readouterr().out


def test_missing_baseline_passes_trivially(monkeypatch, tmp_path, capsys):
    rc = _run(monkeypatch, tmp_path, {"s": {"timings": {"k": 1.0}}}, None)
    assert rc == 0
    assert "first run passes trivially" in capsys.readouterr().out


def test_no_overlap_passes(monkeypatch, tmp_path, capsys):
    rc = _run(monkeypatch, tmp_path,
              {"a": {"timings": {"x": 1.0}}},
              {"b": {"timings": {"y": 1.0}}})
    assert rc == 0
    assert "no overlapping timings" in capsys.readouterr().out
