"""Cost model eqs. (3)-(17) and the Section-III constants."""

import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import make_scenario
from repro.core.cost_model import (LearningParams, comm_energy, comm_time,
                                   comp_energy, comp_time, global_cost,
                                   ra_constants, ra_objective)


def test_learning_params_iteration_counts():
    lp = LearningParams(theta=0.5, epsilon=0.1, mu=14.4, delta=2.17)
    assert abs(lp.local_iters - 14.4 * np.log(2.0)) < 1e-9
    assert abs(lp.edge_iters - 2.17 * np.log(10.0) / 0.5) < 1e-9


def test_primitive_overheads_match_equations():
    sc = make_scenario(4, 2, seed=0)
    dev, lp = sc.dev, sc.lp
    f = jnp.full(4, 2e9)
    beta = jnp.full(4, 0.25)
    bw, n0 = sc.srv.bandwidth[0], sc.srv.noise[0]
    # eq. (3): t = L * c|D| / f
    expect = lp.local_iters * np.asarray(dev.cycles_per_iter) / 2e9
    assert np.allclose(comp_time(dev, f, lp), expect, rtol=1e-6)
    # eq. (4): e = L * alpha/2 * f^2 * c|D|
    expect = lp.local_iters * 0.5 * np.asarray(dev.alpha) * (2e9 ** 2) \
        * np.asarray(dev.cycles_per_iter)
    assert np.allclose(comp_energy(dev, f, lp), expect, rtol=1e-6)
    # eq. (6)/(7): t = d/r, e = p*t
    rate = 0.25 * float(bw) * np.log1p(
        np.asarray(dev.channel_gain) * np.asarray(dev.tx_power) / float(n0))
    assert np.allclose(comm_time(dev, beta, bw, n0),
                       np.asarray(dev.model_nats) / rate, rtol=1e-5)
    assert np.allclose(comm_energy(dev, beta, bw, n0),
                       np.asarray(dev.model_nats) / rate
                       * np.asarray(dev.tx_power), rtol=1e-5)


def test_ra_objective_equals_global_cost_single_server():
    """Problem (18)'s objective must equal the λ-weighted edge cost."""
    sc = make_scenario(6, 1, seed=1)
    lp = sc.lp
    c = ra_constants(sc.dev, sc.srv.bandwidth[0], sc.srv.noise[0], lp)
    mask = jnp.ones(6, bool)
    f = jnp.full(6, 3e9)
    beta = jnp.full(6, 1.0 / 6)
    obj = float(ra_objective(c, mask, f, beta))

    from repro.core.cost_model import edge_cost
    direct = float(edge_cost(sc.dev, mask, f, beta, sc.srv.bandwidth[0],
                             sc.srv.noise[0], lp))
    assert abs(obj - direct) / direct < 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_global_cost_positive_and_finite(seed):
    sc = make_scenario(8, 3, seed=seed)
    assignment = jnp.asarray(np.random.default_rng(seed).integers(0, 3, 8))
    f = jnp.full(8, 2e9)
    beta = jnp.full(8, 0.2)
    e, t, cost = global_cost(sc.dev, sc.srv, assignment, f, beta, sc.lp)
    assert np.isfinite(float(e)) and float(e) > 0
    assert np.isfinite(float(t)) and float(t) > 0
    assert abs(float(cost) - (sc.lp.lambda_e * float(e)
                              + sc.lp.lambda_t * float(t))) < 1e-3 * float(cost)


def test_scenario_table2_ranges():
    sc = make_scenario(32, 5, seed=0)
    d = sc.dev
    assert np.all(np.asarray(d.f_min) == 1e9)
    assert np.all(np.asarray(d.f_max) == 10e9)
    assert np.all(np.asarray(d.tx_power) == np.float32(0.2))
    assert np.all(np.asarray(d.alpha) == np.float32(2e-28))
    assert np.all(np.asarray(d.model_nats) == 25000.0)
    assert np.all(np.asarray(sc.srv.bandwidth) == np.float32(10e6))
    # processing density 30-100 cycle/bit on 5-10 MB
    cpb = np.asarray(d.cycles_per_iter)
    assert np.all(cpb >= 30 * 5e6 * 8) and np.all(cpb <= 100 * 10e6 * 8)
    assert sc.avail.any(axis=0).all(), "every device reaches some server"
