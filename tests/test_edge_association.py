"""Section IV: edge association — monotone improvement, stability,
permission rules, warm-started elasticity."""

import numpy as np
import pytest

from repro.core import make_scenario
from repro.core.edge_association import AssociationEngine, evaluate_scheme


def test_monotone_cost_trace_and_stability():
    sc = make_scenario(18, 4, seed=0)
    eng = AssociationEngine(sc, kind="fast", seed=0)
    res = eng.run_batched("random")
    trace = np.asarray(res.cost_trace)
    assert np.all(np.diff(trace) <= 1e-6 * trace[:-1]), "cost must decrease"
    # stability: re-running from the stable point applies no adjustment
    eng2 = AssociationEngine(sc, kind="fast", seed=0)
    res2 = eng2.run_batched(assignment=res.assignment)
    assert res2.n_adjustments == 0


def test_faithful_algorithm3_converges():
    sc = make_scenario(14, 4, seed=1)
    eng = AssociationEngine(sc, kind="fast", seed=0)
    res = eng.run("random", max_rounds=50)
    assert res.n_rounds < 50, "Algorithm 3 must terminate (Thm. 3)"
    assert res.total_cost <= res.cost_trace[0] + 1e-6


def test_assignment_respects_availability():
    sc = make_scenario(16, 4, seed=2, reach_m=250.0)
    eng = AssociationEngine(sc, kind="fast", seed=0)
    res = eng.run_batched("nearest")
    avail = np.asarray(sc.avail)
    for dev, srv in enumerate(res.assignment):
        assert avail[srv, dev], f"device {dev} assigned to unreachable {srv}"


def test_pareto_permission_stricter_than_utilitarian():
    sc = make_scenario(16, 4, seed=3)
    ut = AssociationEngine(sc, kind="fast", permission="utilitarian",
                           seed=0).run_batched("random")
    pa = AssociationEngine(sc, kind="fast", permission="pareto",
                           seed=0).run_batched("random")
    # the strict pareto reading permits at most as many adjustments
    assert pa.n_adjustments <= ut.n_adjustments


@pytest.mark.slow
def test_hfel_beats_nonassociated_schemes():
    sc = make_scenario(20, 5, seed=4)
    hfel = evaluate_scheme(sc, "hfel", seed=0)
    rnd = evaluate_scheme(sc, "random", seed=0)
    uni = evaluate_scheme(sc, "uniform", seed=0)
    assert hfel.total_cost <= rnd.total_cost * 1.001
    assert hfel.total_cost <= uni.total_cost * 1.001


@pytest.mark.slow
def test_scheme_zoo_runs():
    sc = make_scenario(12, 3, seed=5)
    for scheme in ["hfel", "random", "greedy", "comp_opt", "comm_opt",
                   "uniform", "proportional"]:
        r = evaluate_scheme(sc, scheme, seed=0)
        assert np.isfinite(r.total_cost) and r.total_cost > 0
        # every device assigned somewhere (constraint 17e)
        assert len(r.assignment) == 12
