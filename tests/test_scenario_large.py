"""make_large_scenario and reach_index_map: determinism, density bounds,
cluster invariants, and the no-zero-reach-device guarantee the compacted
association engine depends on."""

import numpy as np
import pytest

from repro.core.scenario import (make_large_scenario, make_scenario,
                                 reach_index_map)


def test_seed_determinism():
    a = make_large_scenario(300, 12, seed=7)
    b = make_large_scenario(300, 12, seed=7)
    np.testing.assert_array_equal(a.avail, b.avail)
    np.testing.assert_array_equal(a.dist, b.dist)
    np.testing.assert_array_equal(np.asarray(a.dev.channel_gain),
                                  np.asarray(b.dev.channel_gain))
    np.testing.assert_array_equal(np.asarray(a.dev.cycles_per_iter),
                                  np.asarray(b.dev.cycles_per_iter))
    c = make_large_scenario(300, 12, seed=8)
    assert not np.array_equal(a.dist, c.dist)


@pytest.mark.parametrize("n,k", [(250, 10), (1000, 20), (2000, 50)])
def test_reach_density_bounds_and_reachability(n, k):
    sc = make_large_scenario(n, k, seed=0)
    assert sc.n_devices == n and sc.n_servers == k
    assert sc.avail.shape == (k, n)
    # every device must reach >= 1 server (constraint 17e; a zero-reach
    # device would also break compacted slot indexing)
    assert sc.avail.any(axis=0).all()
    # restricted-reach regime: sparse but not degenerate
    density = sc.avail.mean()
    assert 0.0 < density < 0.6
    # availability is distance-consistent up to the nearest-server fallback
    reach = 3.0 * 120.0
    by_dist = sc.dist <= reach
    extra = sc.avail & ~by_dist
    fallback_devices = np.flatnonzero(~by_dist.any(axis=0))
    assert set(np.flatnonzero(extra.any(axis=0))) <= set(fallback_devices)
    for dev in fallback_devices:
        # exactly the nearest server was force-enabled
        assert sc.avail[:, dev].sum() == 1
        assert sc.avail[np.argmin(sc.dist[:, dev]), dev]


def test_cluster_size_invariants():
    """Devices drop as clusters around anchor servers: area scales with the
    server count, positions stay in-bounds, and most devices sit within a
    few cluster widths of their nearest server."""
    n, k, spread = 1000, 20, 120.0
    sc = make_large_scenario(n, k, seed=3, spread_m=spread)
    area = 500.0 * np.sqrt(k / 5.0)
    nearest = sc.dist.min(axis=0)
    # Gaussian clusters of width `spread` around a server: the nearest
    # server is at most ~the anchor distance away, so the 99th percentile
    # stays within a few sigma (clipping to the area can only reduce it)
    assert np.quantile(nearest, 0.99) < 4.0 * spread
    assert nearest.max() < area
    assert (sc.dist >= 0).all()


def test_custom_area_and_reach_override():
    sc = make_large_scenario(100, 5, seed=0, area_m=400.0, reach_m=1e6)
    assert sc.avail.all(), "unbounded reach must make everything available"
    assert sc.dist.max() <= np.sqrt(2) * 400.0 + 1e-6


# ---------------------------------------------------------------------------
# reach_index_map
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,reach", [(40, 4, 300.0), (250, 10, None)])
def test_reach_index_map_roundtrip(n, k, reach):
    sc = (make_scenario(n, k, seed=1, reach_m=reach) if reach
          else make_large_scenario(n, k, seed=1))
    ri = reach_index_map(sc.avail)
    counts = sc.avail.sum(axis=1)
    assert ri.r_max == counts.max()
    assert 0.0 < ri.density <= 1.0
    for srv in range(k):
        devices = np.flatnonzero(sc.avail[srv])
        # forward map: ascending reachable devices, then padding
        np.testing.assert_array_equal(ri.idx[srv, :devices.size], devices)
        assert ri.valid[srv].sum() == devices.size
        assert not ri.valid[srv, devices.size:].any()
        # inverse map: slot[srv, idx[srv, r]] == r on valid slots,
        # sentinel r_max everywhere else
        np.testing.assert_array_equal(
            ri.slot[srv, devices], np.arange(devices.size))
        off = np.ones(sc.n_devices, bool)
        off[devices] = False
        assert (ri.slot[srv, off] == ri.r_max).all()


def test_reach_index_map_rejects_zero_reach_device():
    avail = np.ones((3, 5), dtype=bool)
    avail[:, 2] = False
    with pytest.raises(ValueError, match="reach"):
        reach_index_map(avail)
