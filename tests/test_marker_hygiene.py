"""The marker-hygiene enforcement (tests/_marker_hygiene.py) is itself part
of the test-tooling contract: exercise it in a pytest subprocess on a tiny
throwaway suite (no jax import — these run in ~a second each)."""

import os
import pathlib
import subprocess
import sys

import _marker_hygiene

_TESTS_DIR = pathlib.Path(__file__).resolve().parent

_SUITE = """
import time

import pytest


def test_sleepy_unmarked():
    time.sleep(0.4)


@pytest.mark.slow
def test_sleepy_marked():
    time.sleep(0.4)


def test_quick():
    pass


@pytest.fixture
def sleepy_fixture():
    time.sleep(0.4)


def test_slow_fixture_counts(sleepy_fixture):
    pass
"""

_CONFTEST = f"""
import sys

sys.path.insert(0, {str(_TESTS_DIR)!r})
from _marker_hygiene import pytest_runtest_makereport  # noqa: F401


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: slow test")
"""


def _run(tmp_path, limit):
    (tmp_path / "test_tiny.py").write_text(_SUITE)
    (tmp_path / "conftest.py").write_text(_CONFTEST)
    env = dict(os.environ)
    env[_marker_hygiene.ENV_VAR] = limit
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(tmp_path)],
        capture_output=True, text=True, env=env)


def test_over_limit_unmarked_test_fails(tmp_path):
    out = _run(tmp_path, "0.1")
    assert out.returncode == 1, out.stdout + out.stderr
    assert "marker hygiene" in out.stdout
    assert "test_sleepy_unmarked" in out.stdout
    # slow FIXTURE time bills to the test that triggered it
    assert "test_slow_fixture_counts" in out.stdout
    # the slow-marked sibling and the quick test stay green
    assert "2 passed" in out.stdout and "2 failed" in out.stdout


def test_disabled_limit_passes_everything(tmp_path):
    out = _run(tmp_path, "0")
    assert out.returncode == 0, out.stdout + out.stderr
    assert "4 passed" in out.stdout


def test_unparseable_limit_disables(monkeypatch):
    monkeypatch.setenv(_marker_hygiene.ENV_VAR, "not-a-number")
    assert _marker_hygiene.slow_marker_limit_s() == 0.0
    monkeypatch.delenv(_marker_hygiene.ENV_VAR)
    assert _marker_hygiene.slow_marker_limit_s() == 0.0
