"""End-to-end behaviour tests: scheduler -> FL training -> metrics, and a
miniature LM training loop exercising optimizer + checkpoint + pipeline."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import make_scenario
from repro.core.edge_association import AssociationEngine
from repro.data import TokenPipeline, make_mnist_like
from repro.fl import train_federated
from repro.models import build_model
from repro.optim import adamw, apply_updates, clip_by_global_norm


def test_end_to_end_scheduler_into_training():
    """The paper's full loop: scenario -> edge association -> resource
    allocation -> hierarchical training with the scheduled assignment."""
    sc = make_scenario(16, 4, seed=0)
    res = AssociationEngine(sc, kind="fast", seed=0).run_batched("nearest")
    assert res.total_cost <= res.cost_trace[0] + 1e-9

    ds = make_mnist_like(16, seed=0)
    hist = train_federated(ds, method="hfel", assignment=res.assignment,
                           n_servers=4, rounds=8, local_iters=10,
                           edge_iters=5, lr=0.05, eval_every=2)
    assert hist.test_acc[-1] > hist.test_acc[0]
    assert hist.train_loss[-1] < hist.train_loss[0]


def test_end_to_end_lm_training_loop():
    """Tiny LM: loss decreases over a few steps; checkpoint/restore resumes."""
    cfg = get_config("qwen3-0.6b").reduced(vocab_size=128, n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = clip_by_global_norm(adamw(1e-2), 1.0)
    opt_state = opt.init(params)
    pipe = TokenPipeline(cfg.vocab_size, 32, 4, seed=0)

    @jax.jit
    def step(params, opt_state, k, tokens):
        loss, g = jax.value_and_grad(model.loss)(params, {"tokens": tokens})
        upd, opt_state = opt.update(g, opt_state, params, k)
        return apply_updates(params, upd), opt_state, loss

    losses = []
    for k in range(12):
        params, opt_state, loss = step(params, opt_state, k,
                                       jnp.asarray(next(pipe)))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(12, {"params": params}, extras={"loss": losses[-1]})
        s, restored, extras = mgr.restore(template={"params": params})
        assert s == 12
        l2 = float(model.loss(restored["params"],
                              {"tokens": jnp.asarray(next(pipe))}))
        assert np.isfinite(l2)


def test_failure_recovery_round_hook():
    """Failure injection + straggler masking through the round hook keeps
    training sound (no NaNs, accuracy still improves)."""
    from repro.runtime import FailureInjector

    ds = make_mnist_like(12, seed=1)
    fi = FailureInjector(12, p_fail=0.15, seed=0)

    def hook(trainer, r):
        trainer.client_mask = jnp.asarray(fi.step())

    hist = train_federated(ds, method="hfel", n_servers=3, rounds=8,
                           local_iters=5, edge_iters=3, lr=0.05,
                           eval_every=2, round_hook=hook)
    assert np.isfinite(hist.train_loss[-1])
    assert hist.test_acc[-1] > 0.3
