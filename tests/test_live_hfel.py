"""End-to-end live co-simulation tier (repro.fl.live): elastic re-association
during federated training under device churn.

The load-bearing gates:
  * warm/cold swap parity — ``incremental-warm`` and ``periodic-cold`` must
    produce bit-identical assignments at every swap point (the PR-4 parity
    gate lifted into the training loop), hence identical cumulative eq.-(17)
    cost;
  * any re-association policy is at least as cheap (cumulative eq.-17) as
    the frozen ``static`` assignment on a churn scenario;
  * history shapes are stable across ``eval_every`` (round-indexed lists
    always span every round; eval-indexed lists carry their own index).
"""

import jax
import numpy as np
import pytest

from repro.core.assoc_fast import assignment_true_cost
from repro.core.scenario import (device_client_bridge, diff_scenarios,
                                 make_large_scenario, perturb_scenario)
from repro.data import make_mnist_like
from repro.fl import run_live
from repro.fl.live import LiveHFELRunner

N, K = 16, 3
ROUNDS = 4
# heavy churn so every policy decision matters within a handful of rounds
CHURN = dict(drift_m=60.0, move_frac=0.2, flip_frac=0.1, depart_frac=0.15,
             arrive_frac=0.5)


@pytest.fixture(scope="module")
def sc():
    return make_large_scenario(N, K, seed=0)


@pytest.fixture(scope="module")
def ds():
    return make_mnist_like(N, samples_total=400, seed=0)


def _live(sc, ds, policy, **kw):
    kw.setdefault("rounds", ROUNDS)
    kw.setdefault("resolve_every", 2)
    kw.setdefault("churn", CHURN)
    kw.setdefault("seed", 0)
    kw.setdefault("local_iters", 2)
    kw.setdefault("edge_iters", 2)
    return run_live(sc, ds, policy=policy, **kw)


# -- (a) warm/cold parity at every swap point --------------------------------

@pytest.mark.slow
def test_warm_and_cold_policies_swap_bit_identically(sc, ds):
    warm = _live(sc, ds, "incremental-warm")
    cold = _live(sc, ds, "periodic-cold")
    assert warm.swap_rounds == cold.swap_rounds
    assert warm.swap_rounds[0] == 0 and len(warm.swap_rounds) >= 2
    for r, aw, ac in zip(warm.swap_rounds, warm.swap_assignments,
                         cold.swap_assignments):
        np.testing.assert_array_equal(
            aw, ac, err_msg=f"swap assignments diverged at round {r}")
    # identical assignments on identical scenarios => identical costs
    np.testing.assert_allclose(warm.system_cost, cold.system_cost, rtol=1e-6)
    assert abs(warm.cumulative_cost - cold.cumulative_cost) <= (
        1e-6 * cold.cumulative_cost)


def test_incremental_warm_passes_engine_verify_gate(sc, ds):
    """verify=True runs the rerun_incremental cold-rebuild parity assertion
    inside every warm re-solve — it raising is the failure mode."""
    h = _live(sc, ds, "incremental-warm", verify=True)
    assert sum(h.swapped) >= 2


# -- (b) re-association beats (or ties) the frozen assignment ----------------

@pytest.mark.slow
def test_reassociation_cumulative_cost_beats_static(sc, ds):
    static = _live(sc, ds, "static")
    warm = _live(sc, ds, "incremental-warm", resolve_every=1)
    cold = _live(sc, ds, "periodic-cold", resolve_every=1)
    assert warm.cumulative_cost <= static.cumulative_cost * (1 + 1e-9)
    assert cold.cumulative_cost <= static.cumulative_cost * (1 + 1e-9)
    # static performs no descent after round 0
    assert static.moves[1:] == [0] * (static.rounds - 1)
    assert static.swap_rounds == [0]


def test_non_warm_policies_release_the_engine(sc, ds):
    """Only incremental-warm re-enters the round-0 engine; the others must
    not keep its toggle caches resident for the whole run."""
    from repro.fl.live import LiveHFELRunner
    runner = LiveHFELRunner(sc, N, policy="static", churn=CHURN, seed=0)
    h = run_live(sc, ds, policy="static", rounds=2, resolve_every=1,
                 churn=CHURN, seed=0, local_iters=1, edge_iters=1)
    assert h.rounds == 2   # ran fine without the engine
    tr = type("T", (), {"client_mask": None})()
    runner.begin_round(tr, 0)
    assert runner.engine is None


def test_per_round_cost_matches_standalone_evaluator(sc, ds):
    """history.system_cost[r] is assignment_true_cost of the round's
    assignment on the round's scenario — recompute round 0 independently."""
    h = _live(sc, ds, "static", rounds=1)
    e, t, c = assignment_true_cost(sc, h.swap_assignments[0])
    assert h.system_cost[0] == pytest.approx(c, rel=1e-6)
    assert h.system_energy[0] == pytest.approx(e, rel=1e-6)
    assert h.system_delay[0] == pytest.approx(t, rel=1e-6)


def test_true_cost_of_fully_departed_population_is_zero(sc):
    """Churn can legitimately empty a small scenario; the cost accounting
    must record a degenerate (0, 0, 0) round, not abort the simulation."""
    import dataclasses
    sc_empty = dataclasses.replace(sc, active=np.zeros(N, bool))
    assign = np.argmin(np.where(sc.avail, sc.dist, np.inf), axis=0)
    assert assignment_true_cost(sc_empty, assign) == (0.0, 0.0, 0.0)


def test_no_churn_degenerates_to_static(sc, ds):
    """With a zero-churn tick every policy keeps the round-0 stable point:
    no further moves, constant per-round cost."""
    none = dict(drift_m=0.0, move_frac=0.0, flip_frac=0.0, depart_frac=0.0,
                arrive_frac=0.0)
    static = _live(sc, ds, "static", churn=none, rounds=3)
    warm = _live(sc, ds, "incremental-warm", churn=none, rounds=3,
                 resolve_every=1)
    np.testing.assert_allclose(warm.system_cost, static.system_cost,
                               rtol=1e-6)
    assert warm.moves[1:] == [0, 0]
    np.testing.assert_allclose(static.system_cost,
                               [static.system_cost[0]] * 3, rtol=1e-6)


# -- (c) history shape stability across eval_every ---------------------------

@pytest.mark.parametrize("eval_every", [1, 2, 3])
def test_history_lengths_stable_across_eval_every(sc, ds, eval_every):
    h = _live(sc, ds, "incremental-warm", eval_every=eval_every, rounds=5)
    for name in ("system_cost", "system_energy", "system_delay",
                 "assoc_seconds", "swapped", "moves", "n_active",
                 "n_arrived", "n_departed"):
        assert len(getattr(h, name)) == 5, name
    expect_evals = sorted(set(range(0, 5, eval_every)) | {4})
    assert h.train.eval_rounds == expect_evals
    for name in ("test_acc", "train_acc", "train_loss"):
        assert len(getattr(h.train, name)) == len(expect_evals), name
    assert len(h.swap_rounds) == len(h.swap_assignments) == sum(h.swapped)
    d = h.as_dict()
    assert set(d["train"]) == {"test_acc", "train_acc", "train_loss",
                               "eval_rounds"}
    assert d["cumulative_cost"] == pytest.approx(sum(d["system_cost"]))


# -- bridge + delta-composition seams ----------------------------------------

def test_device_client_bridge_validates_and_maps(sc):
    b = device_client_bridge(sc, 10)
    np.testing.assert_array_equal(b.device_of, np.arange(10))
    assert b.n_clients == 10 and b.n_devices == N
    active = np.zeros(N, bool)
    active[[0, 3, 12]] = True
    np.testing.assert_array_equal(b.client_mask(active),
                                  active[:10])
    assign = np.arange(N) % K
    np.testing.assert_array_equal(b.client_assignment(assign), assign[:10])
    assert b.client_of[12] == -1 and b.client_of[3] == 3
    with pytest.raises(ValueError):
        device_client_bridge(sc, N + 1)
    with pytest.raises(ValueError):
        device_client_bridge(sc, 3, device_of=np.array([0, 0, 1]))
    with pytest.raises(ValueError):
        device_client_bridge(sc, 2, device_of=np.array([0, N]))


def test_live_runner_with_fewer_clients_than_devices(sc):
    """Deviceless clients are illegal; clientless devices are fine — the
    bridge masks them out of training while association still places them."""
    ds_small = make_mnist_like(10, samples_total=300, seed=1)
    h = run_live(sc, ds_small, policy="incremental-warm", rounds=2,
                 resolve_every=1, churn=CHURN, seed=0, local_iters=1,
                 edge_iters=1)
    assert h.rounds == 2 and len(h.swap_assignments[0]) == N


def test_diff_scenarios_matches_single_tick_delta(sc):
    sc2, delta = perturb_scenario(sc, seed=7, **CHURN)
    diff = diff_scenarios(sc, sc2)
    np.testing.assert_array_equal(diff.moved, delta.moved)
    np.testing.assert_array_equal(diff.arrived, delta.arrived)
    np.testing.assert_array_equal(diff.departed, delta.departed)
    np.testing.assert_array_equal(diff.avail_flips, delta.avail_flips)
    np.testing.assert_array_equal(diff.eff_flips, delta.eff_flips)
    np.testing.assert_array_equal(diff.stale_servers, delta.stale_servers)


def test_diff_scenarios_composes_two_ticks(sc):
    """The combined diff cancels a depart-then-return device and covers the
    union of both ticks' effective flips."""
    sc1, d1 = perturb_scenario(sc, seed=3, **CHURN)
    sc2, d2 = perturb_scenario(sc1, seed=4, **CHURN)
    diff = diff_scenarios(sc, sc2)
    returned = d1.departed & d2.arrived
    assert not (diff.departed & returned).any()
    assert not (diff.arrived & returned).any()
    np.testing.assert_array_equal(
        diff.eff_flips, sc2.eff_avail != sc.eff_avail)
    with pytest.raises(ValueError):
        diff_scenarios(sc, make_large_scenario(N + 1, K, seed=0))
    # same shape but unrelated scenario: device params differ -> every
    # incremental consumer's cached constants would be silently wrong
    with pytest.raises(ValueError, match="churn-invariant"):
        diff_scenarios(sc, make_large_scenario(N, K, seed=99))
    import dataclasses
    from repro.core.cost_model import LearningParams
    with pytest.raises(ValueError, match="churn-invariant"):
        diff_scenarios(sc, dataclasses.replace(
            sc2, lp=LearningParams(theta=0.25)))


def test_assignment_true_cost_rejects_mismatched_solver(sc):
    from repro.core.edge_association import GroupSolver
    assign = np.argmin(np.where(sc.avail, sc.dist, np.inf), axis=0)
    solver = GroupSolver(sc, "fast", seed=0, profile="default")
    with pytest.raises(ValueError, match="kind"):
        assignment_true_cost(sc, assign, solver=solver, kind="uniform")
    # a screening-profile solver is silently viewed at reference accuracy
    coarse = GroupSolver(sc, "fast", seed=0, profile="coarse")
    assert (assignment_true_cost(sc, assign, solver=coarse)
            == assignment_true_cost(sc, assign, solver=solver))


def test_stable_assignment_handoff_tracks_every_resolve(sc):
    """The engine's stable-point handoff surface: None before the first run,
    then always the latest stable assignment — after a cold run and after an
    incremental rerun (finalize=False fast path) alike."""
    from repro.core.assoc_fast import FastAssociationEngine
    eng = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                rel_tol=1e-3)
    assert eng.stable_assignment is None
    res = eng.run("nearest", exchange_samples=0)
    np.testing.assert_array_equal(eng.stable_assignment, res.assignment)
    sc2, delta = perturb_scenario(sc, seed=11, **CHURN)
    out = eng.rerun_incremental(sc2, delta, exchange_samples=0,
                                finalize=False)
    np.testing.assert_array_equal(eng.stable_assignment, out)
    assert eng.last_moves is not None and eng.last_moves >= 0


def test_runner_rejects_bad_config(sc):
    with pytest.raises(ValueError):
        LiveHFELRunner(sc, N, policy="nope")
    with pytest.raises(ValueError):
        LiveHFELRunner(sc, N, resolve_every=0)
    with pytest.raises(ValueError, match="maps 5 clients"):
        LiveHFELRunner(sc, 10, bridge=device_client_bridge(sc, 5))


# -- sharded engine plumbing (PR-6 follow-on) --------------------------------

def test_sharded_engine_plumbs_and_swaps_bit_identically(sc, ds):
    """shards=/ra_backend= reach every engine the policies construct, and a
    shards=1 live run keeps the bit-identical-assignment contract (hence an
    identical history) vs the classic single-device path."""
    runner = LiveHFELRunner(sc, N, shards=1, ra_backend="xla")
    eng = runner._new_engine(sc)
    assert eng.shards == 1 and eng.ra_backend == "xla"

    kw = dict(rounds=3, resolve_every=1, local_iters=1, edge_iters=1)
    base = _live(sc, ds, "incremental-warm", **kw)
    shard = _live(sc, ds, "incremental-warm", shards=1, **kw)
    assert shard.swap_rounds == base.swap_rounds
    for r, ab, ash in zip(base.swap_rounds, base.swap_assignments,
                          shard.swap_assignments):
        np.testing.assert_array_equal(
            ab, ash, err_msg=f"sharded swap diverged at round {r}")
    np.testing.assert_allclose(shard.system_cost, base.system_cost,
                               rtol=1e-6)


def test_sharded_live_with_exchanges_swaps_bit_identically(sc, ds):
    """PR 10 lifts the exchange_samples=0 sharding restriction: a sharded
    live run with sampled exchanges ON (the engine default) must keep the
    bit-identical-swap contract vs the classic single-device path — the
    replicated pair proposal + all_gather winner fold preserve the
    shards=None RNG stream exactly."""
    shards = min(3, len(jax.devices()))
    kw = dict(rounds=3, resolve_every=1, local_iters=1, edge_iters=1,
              exchange_samples=64)
    base = _live(sc, ds, "incremental-warm", **kw)
    shard = _live(sc, ds, "incremental-warm", shards=shards, verify=True,
                  **kw)
    assert shard.swap_rounds == base.swap_rounds
    for r, ab, ash in zip(base.swap_rounds, base.swap_assignments,
                          shard.swap_assignments):
        np.testing.assert_array_equal(
            ab, ash, err_msg=f"sharded exchange swap diverged at round {r}")
    np.testing.assert_allclose(shard.system_cost, base.system_cost,
                               rtol=1e-6)


# -- the larger configuration, slow tier -------------------------------------

@pytest.mark.slow
def test_live_parity_and_cost_larger_config():
    """N=64/K=6, more rounds, milder churn — the shape of the benchmark run,
    with verify ON inside every warm re-solve."""
    sc = make_large_scenario(64, 6, seed=1)
    ds = make_mnist_like(64, samples_total=1200, seed=1)
    churn = dict(drift_m=60.0, move_frac=0.08, flip_frac=0.03,
                 depart_frac=0.05, arrive_frac=0.3)
    kw = dict(rounds=6, resolve_every=2, churn=churn, seed=1, local_iters=2,
              edge_iters=2)
    warm = run_live(sc, ds, policy="incremental-warm", verify=True, **kw)
    cold = run_live(sc, ds, policy="periodic-cold", **kw)
    static = run_live(sc, ds, policy="static", **kw)
    assert warm.swap_rounds == cold.swap_rounds
    for aw, ac in zip(warm.swap_assignments, cold.swap_assignments):
        np.testing.assert_array_equal(aw, ac)
    assert abs(warm.cumulative_cost - cold.cumulative_cost) <= (
        1e-6 * cold.cumulative_cost)
    assert warm.cumulative_cost <= static.cumulative_cost * (1 + 1e-9)
    assert cold.cumulative_cost <= static.cumulative_cost * (1 + 1e-9)
    # training survived the churn: accuracy improved over the run
    assert warm.train.test_acc[-1] > warm.train.test_acc[0]


# -- streaming admission under capacities ------------------------------------

ADMIT_CHURN = dict(drift_m=60.0, move_frac=0.2, flip_frac=0.1,
                   depart_frac=0.25, arrive_frac=0.5)


class _FakeTrainer:
    """Just enough trainer surface for begin_round: the mask attribute and
    the arrival-readmit hook."""
    client_mask = None

    def __init__(self):
        self.readmits = []

    def readmit_clients(self, mask, assign, k):
        self.readmits.append(np.asarray(mask).copy())


def _capped(sc, caps):
    import dataclasses
    return dataclasses.replace(sc, max_devices=np.asarray(caps, np.int64))


def test_admission_queue_fills_then_drains_without_waking_solver(sc):
    """Arrivals beyond cap land in the overflow queue; the per-round O(K)
    admission tick drains them as churn (and re-solve rebalancing) frees
    headroom — and the admitted view NEVER exceeds a cap at any round."""
    caps = np.array([4, 4, 4])
    # exchange_samples=0: this test pins queue/drain mechanics, not escape
    # moves (satellite coverage for caps+exchanges lives in
    # test_scenario_churn), and the exchange-off solves keep it in the
    # fast tier
    runner = LiveHFELRunner(_capped(sc, caps), N, policy="incremental-warm",
                            resolve_every=2, churn=ADMIT_CHURN, seed=0,
                            exchange_samples=0)
    tr = _FakeTrainer()
    for rd in range(8):
        runner.begin_round(tr, rd)
        load = np.bincount(runner.assignment[runner.sc.active_mask],
                           minlength=K)
        assert (load <= caps).all(), f"cap exceeded at round {rd}: {load}"
        # queued devices are exactly the not-yet-admitted ones
        assert not runner.sc.active_mask[runner._queue].any()
    h = runner.history
    # sum(caps)=12 < 16 active: the initial admission must refuse some
    assert h.n_queued[0] > 0
    assert h.n_active[0] == N - h.n_queued[0]
    # the streaming path admitted queued devices as headroom appeared
    assert sum(h.n_admitted) > 0
    # readmitted arrivals reached the trainer hook
    assert len(tr.readmits) == sum(1 for a in h.n_admitted if a > 0)
    # nothing was dropped: the default overflow bound was never hit
    assert sum(h.n_rejected) == 0


def test_admission_overflow_bound_rejects_oldest(sc):
    """overflow_max=0 degenerates the queue to immediate rejection — every
    refused device is counted, none linger."""
    runner = LiveHFELRunner(_capped(sc, [4, 4, 4]), N, policy="static",
                            churn=ADMIT_CHURN, seed=0, overflow_max=0)
    tr = _FakeTrainer()
    runner.begin_round(tr, 0)
    runner.begin_round(tr, 1)
    h = runner.history
    assert h.n_queued == [0, 0]
    assert h.n_rejected[0] > 0
    with pytest.raises(ValueError, match="overflow_max"):
        LiveHFELRunner(sc, N, overflow_max=-1)


def test_uncapped_history_admission_fields_stay_zero(sc, ds):
    h = _live(sc, ds, "static", rounds=2, resolve_every=1)
    assert h.n_queued == [0, 0]
    assert h.n_admitted == [0, 0]
    assert h.n_rejected == [0, 0]
    d = h.as_dict()
    assert d["n_queued"] == [0, 0] and d["n_rejected"] == [0, 0]


def test_warm_cold_swap_parity_under_binding_caps(ds):
    """The PR-4 parity gate extends to capacitated scenarios: warm and cold
    must agree bit-for-bit at every swap point even while the admission
    queue churns the view between re-solves."""
    scc = make_large_scenario(N, K, seed=0, cap_slack=1.0)
    kw = dict(rounds=4, resolve_every=2, churn=ADMIT_CHURN, seed=0,
              local_iters=1, edge_iters=1)
    warm = run_live(scc, ds, policy="incremental-warm", verify=True, **kw)
    cold = run_live(scc, ds, policy="periodic-cold", **kw)
    assert warm.swap_rounds == cold.swap_rounds
    for r, aw, ac in zip(warm.swap_rounds, warm.swap_assignments,
                         cold.swap_assignments):
        np.testing.assert_array_equal(aw, ac,
                                      err_msg=f"diverged at round {r}")
    np.testing.assert_allclose(warm.system_cost, cold.system_cost, rtol=1e-6)
