"""Import hypothesis when available, else a minimal stub.

With the stub, ``@given`` tests are individually skip-marked while every
other test in the importing module still runs — a module-level
``pytest.importorskip`` would silently drop the non-property tests too.
Install the real thing via requirements-dev.txt.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _StrategyStub:
        """st.<anything>(...) placeholder; never executed (tests are skipped)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (see requirements-dev.txt)")
