"""Import hypothesis when available, else a minimal deterministic shim.

The shim implements just enough of ``@given``/``@settings``/``strategies``
for this repo's property tests to RUN instead of skipping wholesale: each
``@given`` test executes a fixed-seed sample of examples (seeded from the
test name, so the drawn cases are stable across runs and machines and a
failure is reproducible by rerunning the same test). It is NOT a shrinking
property-test engine — install the real thing via requirements-dev.txt for
exploratory runs; CI-grade determinism is exactly what the shim provides.

Supported surface (what the test files use):
  * ``st.integers(lo, hi)`` / ``st.floats(lo, hi)`` — inclusive-low bounds,
    drawn uniformly.
  * ``@settings(max_examples=N, deadline=...)`` — ``max_examples`` caps the
    shim's sample (itself bounded by ``SHIM_MAX_EXAMPLES`` to keep tier-1
    wall time flat); ``deadline`` is ignored.
  * ``@given(**kwargs_strategies)`` — keyword style only, like the tests.
"""

import hashlib

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False
    import numpy as _np

    # fixed-seed sample size per property; small because every example of
    # this repo's properties runs real (jitted) solvers
    SHIM_MAX_EXAMPLES = 4

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

    st = _StrategiesModule()

    def settings(*args, max_examples=None, **kwargs):
        def deco(fn):
            if max_examples is not None:
                fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        bad = [k for k, s in strategies.items()
               if not isinstance(s, _Strategy)]
        if bad:
            raise TypeError(f"shim @given got non-strategies for {bad}; "
                            "use st.integers/st.floats")

        def deco(fn):
            inner_max = getattr(fn, "_shim_max_examples", None)

            def wrapper(*args, **kwargs):
                # name-derived seed: stable across runs/processes (unlike
                # hash()), distinct per test
                digest = hashlib.sha256(
                    fn.__qualname__.encode()).digest()
                rng = _np.random.default_rng(
                    int.from_bytes(digest[:8], "little"))
                # @settings may sit above @given (attr lands on wrapper) or
                # below it (attr landed on fn before we wrapped it)
                declared = getattr(wrapper, "_shim_max_examples",
                                   inner_max if inner_max is not None
                                   else SHIM_MAX_EXAMPLES)
                n = min(declared, SHIM_MAX_EXAMPLES)
                for _ in range(max(n, 1)):
                    drawn = {k: s._draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified on shim example {drawn!r}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
