"""Compacted reachable-slot sweep engine: parity with the dense fast engine,
exchange handling, cache consistency, and the two-tier descent driver."""

import numpy as np
import pytest

from repro.core import make_scenario
from repro.core import resource_allocation as ra
from repro.core.assoc_fast import FastAssociationEngine
from repro.core.scenario import make_large_scenario, reach_index_map

PARITY_CASES = [(14, 3, 0), (18, 4, 1), (16, 4, 2)]


@pytest.mark.parametrize("n,k,seed", PARITY_CASES)
def test_compact_parity_dense_avail(n, k, seed):
    """On fully dense availability (R == N) the compacted sweep must be a
    pure re-indexing of the dense one: same stable assignment, same cost."""
    sc = make_scenario(n, k, seed=seed)
    dense = FastAssociationEngine(sc, kind="fast", seed=0, compact=False).run(
        "nearest", exchange_samples=0)
    comp = FastAssociationEngine(sc, kind="fast", seed=0, compact=True).run(
        "nearest", exchange_samples=0)
    assert abs(comp.total_cost - dense.total_cost) <= 1e-4 * dense.total_cost
    assert np.array_equal(comp.assignment, dense.assignment)


@pytest.mark.parametrize("n,k,seed", PARITY_CASES)
def test_compact_parity_sparse_avail(n, k, seed):
    """Restricted reach (the regime compaction targets): same stable point
    as the dense fast engine, deterministic transfers only."""
    sc = make_scenario(n, k, seed=seed, reach_m=300.0)
    dense = FastAssociationEngine(sc, kind="fast", seed=0, compact=False).run(
        "nearest", exchange_samples=0)
    comp = FastAssociationEngine(sc, kind="fast", seed=0, compact=True).run(
        "nearest", exchange_samples=0)
    assert abs(comp.total_cost - dense.total_cost) <= 1e-4 * dense.total_cost
    assert np.array_equal(comp.assignment, dense.assignment)
    assert comp.n_adjustments == dense.n_adjustments


@pytest.mark.slow
def test_compact_pareto_permission_parity():
    """Pareto permission rule must gate identically in compacted space."""
    sc = make_scenario(12, 3, seed=7, reach_m=300.0)
    for permission in ("utilitarian", "pareto"):
        dense = FastAssociationEngine(
            sc, kind="fast", permission=permission, seed=0,
            compact=False).run("nearest", exchange_samples=0)
        comp = FastAssociationEngine(
            sc, kind="fast", permission=permission, seed=0,
            compact=True).run("nearest", exchange_samples=0)
        assert comp.n_adjustments == dense.n_adjustments, permission
        assert np.array_equal(comp.assignment, dense.assignment), permission


def test_compact_auto_selection():
    dense_sc = make_scenario(12, 3, seed=0)            # everything reachable
    sparse_sc = make_scenario(16, 4, seed=1, reach_m=300.0)
    assert not FastAssociationEngine(dense_sc, kind="fast", seed=0).compact
    assert FastAssociationEngine(sparse_sc, kind="fast", seed=0).compact


@pytest.mark.slow
def test_compact_auto_promotes_bucketed_on_padding():
    """``compact="auto"`` must dispatch on the measured padded-slot
    threshold: lightly padded flat maps stay flat, heavily padded (skewed
    reach-count) maps promote to the bucketed adaptive-width sweep."""
    from repro.core.assoc_fast import BUCKETED_AUTO_THRESHOLD

    # clustered large scenario: skewed reach counts, pf ~ 0.32 > threshold
    skewed = make_large_scenario(120, 8, seed=0)
    pf_skewed = reach_index_map(skewed.avail).padded_fraction
    assert pf_skewed > BUCKETED_AUTO_THRESHOLD
    eng = FastAssociationEngine(skewed, kind="fast", seed=0, compact="auto")
    assert eng.compact == "bucketed"
    assert eng.reach_buckets is not None and len(eng._buckets) > 1

    # uniform small scenario: sparse but barely padded, pf ~ 0.17
    flat = make_scenario(16, 4, seed=1, reach_m=300.0)
    pf_flat = reach_index_map(flat.avail).padded_fraction
    assert pf_flat < BUCKETED_AUTO_THRESHOLD
    eng = FastAssociationEngine(flat, kind="fast", seed=0, compact="auto")
    assert eng.compact is True
    assert eng.reach_buckets is None

    # the auto choice must not change the stable point (it never can: all
    # spaces share move selection) — spot-check against explicit flat
    auto_res = FastAssociationEngine(
        skewed, kind="fast", seed=0, profile="coarse").run(
        "nearest", max_moves=8, exchange_samples=0)
    flat_res = FastAssociationEngine(
        skewed, kind="fast", seed=0, profile="coarse", compact=True).run(
        "nearest", max_moves=8, exchange_samples=0)
    assert np.array_equal(auto_res.assignment, flat_res.assignment)


def test_compact_exchanges_applied_and_improve():
    """Exchange moves must be exercised in compacted space: from the
    transfers-only stable point no transfer is permitted, so any further
    improvement can only come from an applied exchange (seed chosen so one
    fires)."""
    sc = make_scenario(16, 4, seed=1, reach_m=300.0)
    no_ex = FastAssociationEngine(sc, kind="fast", seed=0, compact=True).run(
        "nearest", exchange_samples=0)
    ex = FastAssociationEngine(sc, kind="fast", seed=0, compact=True).run(
        "nearest", exchange_samples=64)
    assert ex.total_cost < no_ex.total_cost * (1 - 1e-5)
    assert ex.n_adjustments > no_ex.n_adjustments
    avail = np.asarray(sc.avail)
    for dev, srv in enumerate(ex.assignment):
        assert avail[srv, dev]


def test_compact_toggle_cache_matches_uncached_solves():
    """The compacted toggle cache must agree with from-scratch dense-mask
    group solves on every VALID slot (padded slots carry garbage by design
    and must stay excluded)."""
    sc = make_scenario(16, 4, seed=2, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact=True)
    eng.run("nearest", exchange_samples=0)
    st = eng.last_state
    reach = st["reach"]
    member = st["member"]
    cloud = np.asarray(eng.cloud_const)

    def fresh_cost(server, mask):
        sol = eng.solver.solve_batch(np.array([server]), mask[None, :])
        base = float(np.asarray(sol.cost)[0])
        return base + (cloud[server] if mask.any() else 0.0)

    k = sc.n_servers
    for s in range(k):
        # compacted membership mirrors the dense mask row
        np.testing.assert_array_equal(
            st["member_compact"][s, reach.valid[s]],
            member[s, reach.idx[s, reach.valid[s]]])
        assert fresh_cost(s, member[s]) == pytest.approx(
            float(st["cur_cost"][s]), rel=1e-5, abs=1e-6)
    rng = np.random.default_rng(0)
    for _ in range(8):
        s = int(rng.integers(0, k))
        slots = np.flatnonzero(reach.valid[s])
        r = int(rng.choice(slots))
        toggled = member[s].copy()
        d = reach.idx[s, r]
        toggled[d] = ~toggled[d]
        assert fresh_cost(s, toggled) == pytest.approx(
            float(st["toggle_cost_compact"][s, r]), rel=1e-5, abs=1e-6)


def test_compact_stability_and_monotone_trace():
    sc = make_scenario(18, 4, seed=0, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact=True)
    res = eng.run("random")
    trace = np.asarray(res.cost_trace)
    assert np.all(np.diff(trace) <= 1e-6 * trace[:-1]), "cost must decrease"
    res2 = FastAssociationEngine(sc, kind="fast", seed=0, compact=True).run(
        assignment=res.assignment)
    assert res2.n_adjustments == 0


def test_compact_scheme_kinds():
    sc = make_scenario(12, 3, seed=6, reach_m=300.0)
    for kind in ("comp_only", "uniform", "proportional"):
        res = FastAssociationEngine(sc, kind=kind, seed=0, compact=True).run(
            "nearest", exchange_samples=8)
        assert np.isfinite(res.total_cost) and res.total_cost > 0


def test_compact_rejects_unreachable_device():
    sc = make_scenario(10, 3, seed=0)
    sc.avail[:, 0] = False
    with pytest.raises(ValueError):
        FastAssociationEngine(sc, kind="fast", seed=0, compact=True)
    # auto mode falls back to the dense path instead of failing
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact="auto")
    assert not eng.compact


def test_compact_rejects_out_of_reach_assignment():
    """A caller-supplied assignment that violates reach has no slot in
    compacted space and would silently corrupt the sweep — must raise."""
    sc = make_scenario(16, 4, seed=2, reach_m=300.0)
    avail = np.asarray(sc.avail)
    dev = int(np.argmin(avail.sum(axis=0)))     # device with restricted reach
    srv = int(np.flatnonzero(~avail[:, dev])[0])
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact=True)
    bad = eng.initial_assignment("nearest")
    bad[dev] = srv
    with pytest.raises(ValueError, match="within\\s+reach"):
        eng.run(assignment=bad, exchange_samples=0)
    with pytest.raises(ValueError, match="within\\s+reach"):
        eng.run_tiered(assignment=bad, exchange_samples=0)


def test_evaluate_assignment_matches_finalize():
    """evaluate_assignment must reproduce the reference-accuracy total_cost
    _finalize reports for the same assignment."""
    sc = make_scenario(14, 3, seed=0, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse")
    res = eng.run("nearest", exchange_samples=0)
    ev = eng.evaluate_assignment(res.assignment)
    assert abs(ev - res.total_cost) <= 1e-5 * res.total_cost


# ---------------------------------------------------------------------------
# Bucketed (adaptive slot width) sweeps
# ---------------------------------------------------------------------------

def test_reach_index_map_bucketed_structure():
    """Binary buckets must partition the servers, keep per-server slot maps
    consistent with avail, and strictly reduce padding on skewed reach."""
    sc = make_large_scenario(250, 10, seed=0)
    avail = np.asarray(sc.avail)
    flat = reach_index_map(avail)
    rbk = reach_index_map(avail, bucketed=True)
    counts = avail.sum(axis=1)
    seen = np.zeros(sc.n_servers, dtype=int)
    for b, bucket in enumerate(rbk.buckets):
        assert bucket.width == counts[bucket.servers].max()
        for row, srv in enumerate(bucket.servers):
            seen[srv] += 1
            assert rbk.bucket_of[srv] == b and rbk.row_of[srv] == row
            reach = np.flatnonzero(avail[srv])
            np.testing.assert_array_equal(bucket.idx[row, :reach.size], reach)
            assert bucket.valid[row, :reach.size].all()
            assert not bucket.valid[row, reach.size:].any()
            # the global slot map inverts the bucket's index map
            np.testing.assert_array_equal(
                rbk.slot[srv, reach], np.arange(reach.size))
            assert (rbk.slot[srv, ~avail[srv]] == rbk.r_max).all()
    assert (seen == 1).all(), "buckets must partition the servers"
    # skewed reach counts -> narrower buckets waste strictly fewer slots
    assert rbk.padded_fraction < flat.padded_fraction


@pytest.mark.parametrize("n,k,seed", PARITY_CASES)
def test_bucketed_matches_flat_compact_stable_point(n, k, seed):
    """Bucketed-vs-flat gate (skewed reach): per-bucket slot widths must not
    change move selection — same stable assignment, same move count."""
    sc = make_scenario(n, k, seed=seed, reach_m=300.0)
    flat = FastAssociationEngine(sc, kind="fast", seed=0, compact=True).run(
        "nearest", exchange_samples=0)
    bucketed = FastAssociationEngine(
        sc, kind="fast", seed=0, compact="bucketed").run(
        "nearest", exchange_samples=0)
    assert np.array_equal(bucketed.assignment, flat.assignment)
    assert bucketed.n_adjustments == flat.n_adjustments
    assert (abs(bucketed.total_cost - flat.total_cost)
            <= 1e-4 * flat.total_cost)


@pytest.mark.slow
def test_bucketed_exchanges_and_availability():
    """The exchange branch must work across buckets: cost no worse than the
    transfers-only stable point and every placement stays within reach."""
    sc = make_scenario(16, 4, seed=1, reach_m=300.0)
    no_ex = FastAssociationEngine(
        sc, kind="fast", seed=0, compact="bucketed").run(
        "nearest", exchange_samples=0)
    ex = FastAssociationEngine(
        sc, kind="fast", seed=0, compact="bucketed").run(
        "nearest", exchange_samples=64)
    assert ex.total_cost <= no_ex.total_cost * (1 + 1e-6)
    avail = np.asarray(sc.avail)
    for dev, srv in enumerate(ex.assignment):
        assert avail[srv, dev]


def test_bucketed_toggle_cache_matches_uncached_solves():
    """Every bucket's toggle cache must agree with from-scratch dense-mask
    group solves on valid slots."""
    sc = make_scenario(16, 4, seed=2, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact="bucketed")
    eng.run("nearest", exchange_samples=0)
    st = eng.last_state
    rbk = st["reach_buckets"]
    member = st["member"]
    cloud = np.asarray(eng.cloud_const)

    def fresh_cost(server, mask):
        sol = eng.solver.solve_batch(np.array([server]), mask[None, :])
        base = float(np.asarray(sol.cost)[0])
        return base + (cloud[server] if mask.any() else 0.0)

    for b, bucket in enumerate(rbk.buckets):
        toggle = st["toggle_cost_buckets"][b]
        for row, srv in enumerate(bucket.servers):
            assert fresh_cost(srv, member[srv]) == pytest.approx(
                float(st["cur_cost"][srv]), rel=1e-5, abs=1e-6)
            for r in np.flatnonzero(bucket.valid[row])[:4]:
                toggled = member[srv].copy()
                d = bucket.idx[row, r]
                toggled[d] = ~toggled[d]
                assert fresh_cost(srv, toggled) == pytest.approx(
                    float(toggle[row, r]), rel=1e-5, abs=1e-6)


def test_bucketed_rejects_out_of_reach_assignment():
    sc = make_scenario(16, 4, seed=2, reach_m=300.0)
    avail = np.asarray(sc.avail)
    dev = int(np.argmin(avail.sum(axis=0)))
    srv = int(np.flatnonzero(~avail[:, dev])[0])
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact="bucketed")
    bad = eng.initial_assignment("nearest")
    bad[dev] = srv
    with pytest.raises(ValueError, match="within\\s+reach"):
        eng.run(assignment=bad, exchange_samples=0)


def test_evaluate_scheme_bucketed_dispatch():
    from repro.core.edge_association import evaluate_scheme
    sc = make_scenario(12, 3, seed=1, reach_m=300.0)
    res = evaluate_scheme(sc, "hfel", seed=0, compact="bucketed")
    assert np.isfinite(res.total_cost) and res.total_cost > 0


# ---------------------------------------------------------------------------
# Two-tier descent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,seed", PARITY_CASES)
def test_two_tier_matches_default_only(n, k, seed):
    """Deterministic two-tier gate: coarse sweep + default polish must land
    within 1e-3 relative cost of a pure default-profile run."""
    sc = make_scenario(n, k, seed=seed)
    full = FastAssociationEngine(sc, kind="fast", seed=0).run(
        "nearest", exchange_samples=0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0)
    tiered = eng.run_tiered("nearest", exchange_samples=0)
    assert abs(tiered.total_cost - full.total_cost) <= 1e-3 * full.total_cost
    assert len(eng.last_tier_moves) == 2
    assert tiered.n_adjustments == sum(eng.last_tier_moves)


def test_two_tier_from_stable_point_is_noop():
    sc = make_scenario(14, 3, seed=0)
    full = FastAssociationEngine(sc, kind="fast", seed=0).run(
        "nearest", exchange_samples=0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0)
    tiered = eng.run_tiered(assignment=full.assignment, exchange_samples=0)
    assert eng.last_tier_moves[-1] == 0
    assert abs(tiered.total_cost - full.total_cost) <= 1e-5 * full.total_cost


def test_two_tier_compact_sparse():
    sc = make_scenario(18, 4, seed=1, reach_m=300.0)
    full = FastAssociationEngine(sc, kind="fast", seed=0, compact=True).run(
        "nearest", exchange_samples=0)
    tiered = FastAssociationEngine(
        sc, kind="fast", seed=0, compact=True).run_tiered(
        "nearest", exchange_samples=0)
    assert abs(tiered.total_cost - full.total_cost) <= 1e-3 * full.total_cost


def test_resolve_tiers():
    assert ra.resolve_tiers("two_tier") == ("coarse", "default")
    assert ra.resolve_tiers("default_only") == ("default",)
    assert ra.resolve_tiers("coarse") == ("coarse",)
    assert ra.resolve_tiers(("screen", "default")) == ("screen", "default")
    with pytest.raises(ValueError):
        ra.resolve_tiers("nope")
    with pytest.raises(ValueError):
        ra.resolve_tiers(())


def test_evaluate_scheme_tiered_dispatch():
    from repro.core.edge_association import evaluate_scheme
    sc = make_scenario(12, 3, seed=1, reach_m=300.0)
    res = evaluate_scheme(sc, "hfel", seed=0, tiers="two_tier")
    assert np.isfinite(res.total_cost) and res.total_cost > 0
    with pytest.raises(ValueError):
        evaluate_scheme(sc, "hfel", seed=0, engine="batched",
                        tiers="two_tier")
