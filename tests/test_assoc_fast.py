"""Device-resident association engine: parity with the host reference,
permission semantics, and toggle-cache consistency."""

import numpy as np
import pytest

from repro.core import make_scenario
from repro.core.assoc_fast import FastAssociationEngine
from repro.core.edge_association import AssociationEngine
from repro.core.scenario import make_large_scenario

PARITY_CASES = [(14, 3, 0), (18, 4, 1), (16, 4, 2)]


@pytest.mark.parametrize("n,k,seed", PARITY_CASES)
def test_parity_with_reference_stable_point(n, k, seed):
    """With exchanges disabled both engines are deterministic steepest
    transfer descent and must land on the same stable point (the PR's
    1e-4 parity gate); with exchanges the fast engine must not be worse."""
    sc = make_scenario(n, k, seed=seed)
    ref = AssociationEngine(sc, kind="fast", seed=0).run_batched(
        "nearest", exchange_samples=0)
    fast = FastAssociationEngine(sc, kind="fast", seed=0).run(
        "nearest", exchange_samples=0)
    assert abs(fast.total_cost - ref.total_cost) <= 1e-4 * ref.total_cost
    assert fast.total_cost <= ref.total_cost + 1e-4 * ref.total_cost
    # steepest descent with identical tie-breaking: same stable assignment
    assert np.array_equal(fast.assignment, ref.assignment)


def test_parity_with_exchanges_not_worse():
    sc = make_scenario(16, 4, seed=3)
    ref = AssociationEngine(sc, kind="fast", seed=0).run_batched("nearest")
    fast = FastAssociationEngine(sc, kind="fast", seed=0).run("nearest")
    # exchange sampling differs (numpy vs jax PRNG); both must reach a
    # stable point no worse than a few percent of each other
    assert fast.total_cost <= ref.total_cost * 1.02


@pytest.mark.slow
def test_permission_semantics_match_reference_move_for_move():
    """Tiny fixture, no exchanges: the fast engine must replicate the
    reference engine's applied moves exactly under both permission rules."""
    sc = make_scenario(10, 3, seed=7)
    for permission in ("utilitarian", "pareto"):
        ref = AssociationEngine(sc, kind="fast", permission=permission,
                                seed=0).run_batched("nearest",
                                                    exchange_samples=0)
        fast = FastAssociationEngine(sc, kind="fast", permission=permission,
                                     seed=0).run("nearest",
                                                 exchange_samples=0)
        assert fast.n_adjustments == ref.n_adjustments, permission
        assert np.array_equal(fast.assignment, ref.assignment), permission
        np.testing.assert_allclose(np.asarray(fast.cost_trace),
                                   np.asarray(ref.cost_trace),
                                   rtol=1e-4)


def test_pareto_at_most_utilitarian_moves():
    sc = make_scenario(12, 3, seed=5)
    ut = FastAssociationEngine(sc, kind="fast", permission="utilitarian",
                               seed=0).run("random", exchange_samples=0)
    pa = FastAssociationEngine(sc, kind="fast", permission="pareto",
                               seed=0).run("random", exchange_samples=0)
    assert pa.n_adjustments <= ut.n_adjustments


def test_toggle_cache_matches_uncached_solves():
    """The incremental bitset/toggle cache must agree with from-scratch
    group solves at the stable point — both the current-group costs and a
    sample of single-device-toggled variants."""
    sc = make_scenario(12, 3, seed=4)
    eng = FastAssociationEngine(sc, kind="fast", seed=0)
    eng.run("nearest", exchange_samples=0)
    st = eng.last_state
    member = st["member"]
    k, n = member.shape
    cloud = np.asarray(eng.cloud_const)

    def fresh_cost(server, mask):
        sol = eng.solver.solve_batch(np.array([server]), mask[None, :])
        base = float(np.asarray(sol.cost)[0])
        return base + (cloud[server] if mask.any() else 0.0)

    for s in range(k):
        assert fresh_cost(s, member[s]) == pytest.approx(
            float(st["cur_cost"][s]), rel=1e-5, abs=1e-6)
    rng = np.random.default_rng(0)
    for s, d in zip(rng.integers(0, k, 6), rng.integers(0, n, 6)):
        toggled = member[s].copy()
        toggled[d] = ~toggled[d]
        assert fresh_cost(s, toggled) == pytest.approx(
            float(st["toggle_cost"][s, d]), rel=1e-5, abs=1e-6)


def test_monotone_trace_stability_and_availability():
    sc = make_scenario(18, 4, seed=0, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0)
    res = eng.run("random")
    trace = np.asarray(res.cost_trace)
    assert np.all(np.diff(trace) <= 1e-6 * trace[:-1]), "cost must decrease"
    avail = np.asarray(sc.avail)
    for dev, srv in enumerate(res.assignment):
        assert avail[srv, dev]
    # stability: restarting from the stable point applies no adjustment
    res2 = FastAssociationEngine(sc, kind="fast", seed=0).run(
        assignment=res.assignment)
    assert res2.n_adjustments == 0


def test_dense_path_is_identity_bucket():
    """The dense sweep must be the unified kernel configured with identity
    index maps — idx[k] = arange(N), every slot exists, candidacy gated by
    avail — not a separate code path."""
    sc = make_scenario(12, 3, seed=1, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact=False)
    assert len(eng._buckets) == 1
    b = eng._buckets[0]
    n, k = sc.n_devices, sc.n_servers
    np.testing.assert_array_equal(
        np.asarray(b.idx), np.tile(np.arange(n), (k, 1)))
    assert np.asarray(b.exists).all()
    np.testing.assert_array_equal(np.asarray(b.ok), np.asarray(sc.avail))
    np.testing.assert_array_equal(
        np.asarray(eng._slot_of), np.tile(np.arange(n), (k, 1)))


@pytest.mark.parametrize("compact", [False, True, "bucketed"])
def test_identity_and_slot_maps_move_for_move_vs_reference(compact):
    """Every sweep-space configuration of the unified kernel must reproduce
    the host reference engine's applied moves exactly at
    ``exchange_samples=0`` (the PR-1 dense gate, now covering all maps)."""
    sc = make_scenario(16, 4, seed=2, reach_m=300.0)
    ref = AssociationEngine(sc, kind="fast", seed=0).run_batched(
        "nearest", exchange_samples=0)
    fast = FastAssociationEngine(sc, kind="fast", seed=0, compact=compact).run(
        "nearest", exchange_samples=0)
    assert fast.n_adjustments == ref.n_adjustments
    assert np.array_equal(fast.assignment, ref.assignment)
    np.testing.assert_allclose(np.asarray(fast.cost_trace),
                               np.asarray(ref.cost_trace), rtol=1e-4)


def test_large_scenario_generator_shapes():
    sc = make_large_scenario(2000, 50, seed=0)
    assert sc.n_devices == 2000 and sc.n_servers == 50
    assert sc.avail.shape == (50, 2000)
    assert sc.avail.any(axis=0).all(), "every device reaches some server"
    # sparse availability: restricted reach keeps the candidate set local
    assert sc.avail.mean() < 0.5


def test_scheme_kinds_run_on_fast_engine():
    sc = make_scenario(10, 3, seed=6)
    for kind in ("comp_only", "uniform", "proportional"):
        res = FastAssociationEngine(sc, kind=kind, seed=0).run(
            "nearest", exchange_samples=8)
        assert np.isfinite(res.total_cost) and res.total_cost > 0
