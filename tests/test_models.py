"""Per-architecture smoke tests (reduced configs) + decode/forward parity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

RNG = jax.random.key(0)


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(RNG, (b, s + 1), 0,
                                          cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            RNG, (b, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            RNG, (b, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    logits = model.logits(params, batch)
    s = batch["tokens"].shape[1] - 1
    expect_s = s + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    # one SGD step must change params and keep the loss finite
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    new = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = model.loss(new, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "olmo-1b", "qwen2-7b",
                                  "mamba2-1.3b", "deepseek-v2-lite-16b",
                                  "zamba2-2.7b", "whisper-large-v3"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # avoid capacity-related drop differences between prefill and decode
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 2, 8
    batch = _batch(cfg, b=b, s=s)
    full = np.asarray(model.logits(params, batch), np.float32)
    if cfg.family == "vlm":
        full = full[:, cfg.n_vision_tokens:]

    cache = model.decode_init(params, batch, max_len=s + 4,
                              dtype=jnp.float32)
    toks = batch["tokens"]
    for t in range(s):
        logits, cache = model.decode_step(params, cache, toks[:, t])
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), full[:, t],
            atol=2e-3, rtol=2e-3,
            err_msg=f"{arch}: decode/forward mismatch at t={t}")


def test_vlm_prefix_changes_logits():
    cfg = get_config("internvl2-1b").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    l1 = model.logits(params, batch)
    batch2 = dict(batch)
    batch2["prefix_embeds"] = batch["prefix_embeds"] + 1.0
    l2 = model.logits(params, batch2)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_param_count_estimates():
    """Config param estimates must land near their advertised sizes."""
    expectations = {
        "qwen2-7b": (7e9, 8.5e9),
        "qwen3-32b": (30e9, 35e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "mamba2-1.3b": (1.1e9, 1.6e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"
    active = get_config("kimi-k2-1t-a32b").active_param_count()
    assert 25e9 <= active <= 40e9, active


def test_moe_capacity_drops_are_bounded():
    from repro.models.moe import moe_apply, moe_init
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    params = moe_init(RNG, cfg)
    x = jax.random.normal(RNG, (2, 16, cfg.d_model))
    y, aux = moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0

    # gradients flow to the router and experts
    def loss(p):
        out, a = moe_apply(p, cfg, x)
        return jnp.sum(out ** 2) + a

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
    assert float(jnp.abs(g["experts"]["wi"]["w"]).sum()) > 0
