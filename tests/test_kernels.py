"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd

KEY = jax.random.key(42)


@pytest.mark.parametrize("shape,causal,blocks", [
    ((1, 128, 4, 32), True, (32, 32)),
    ((2, 256, 8, 64), True, (64, 128)),
    ((2, 128, 4, 64), False, (64, 64)),
    ((1, 512, 2, 16), True, (128, 64)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, causal, blocks, dtype):
    b, s, h, d = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], shape, dtype)
    k = jax.random.normal(ks[1], shape, dtype)
    v = jax.random.normal(ks[2], shape, dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=blocks[0],
                              block_kv=blocks[1], interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_gqa():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    out = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_kv=64,
                              interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_flash_attention_vjp_matches_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, 32, 32) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("causal,sched", [(True, "triangle"),
                                          (True, "full"), (False, "full")])
def test_blocked_attention_flash_vjp_matches_autodiff(causal, sched):
    from repro.models.attention import blocked_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))

    def loss(mode):
        def f(q, k, v):
            return jnp.sum(blocked_attention(
                q, k, v, causal=causal, schedule=sched, block_q=32,
                block_kv=32, vjp_mode=mode) ** 2)
        return f

    v1, g1 = jax.value_and_grad(loss("autodiff"), argnums=(0, 1, 2))(q, k, v)
    v2, g2 = jax.value_and_grad(loss("flash"), argnums=(0, 1, 2))(q, k, v)
    assert abs(float(v1 - v2)) < 1e-4 * abs(float(v1))
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


@pytest.mark.parametrize("rows,d", [(64, 128), (1000, 256), (3, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d), dtype)
    scale = jax.random.normal(jax.random.key(1), (d,), dtype) * 0.1 + 1.0
    out = ops.rmsnorm(x, scale, block_rows=32)
    expect = ref.rmsnorm_ref(x, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("c,p,block", [(4, 100, 64), (32, 4096, 1024),
                                       (1, 17, 8)])
def test_hier_aggregate_sweep(c, p, block):
    u = jax.random.normal(KEY, (c, p))
    w = jax.random.uniform(jax.random.key(2), (c,)) + 0.05
    out = ops.hier_aggregate(u, w, block_p=block)
    expect = ref.hier_aggregate_ref(u, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


def test_hier_aggregate_tree_equals_weighted_mean():
    trees = [{"w": jnp.full((3, 3), float(i)), "b": jnp.full((2,), float(i))}
             for i in range(4)]
    weights = jnp.asarray([1.0, 1.0, 1.0, 5.0])
    out = ops.hier_aggregate_tree(trees, weights)
    expect = (0 + 1 + 2 + 5 * 3) / 8.0
    assert np.allclose(out["w"], expect) and np.allclose(out["b"], expect)


@pytest.mark.parametrize("nc,b,h,n,p", [(4, 1, 2, 8, 16), (16, 2, 4, 32, 8)])
def test_ssd_state_scan_sweep(nc, b, h, n, p):
    states = jax.random.normal(KEY, (nc, b, h, n, p))
    decay = jax.random.uniform(jax.random.key(3), (nc, b, h),
                               minval=0.3, maxval=1.0)
    init = jax.random.normal(jax.random.key(4), (b, h, n, p))
    ent, fin = ops.ssd_state_scan(states, decay, init)
    ent_r, fin_r = ref.ssd_state_scan_ref(states, decay, init)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_r), atol=1e-5)
