"""hfellint fixture corpus: one known-violation and one known-clean snippet
per rule, jit-scope detection across the repo's wrapping idioms, pragma
suppression, baseline round-trip/idempotence, and the subprocess exit-code
contract of scripts/lint.py."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (Finding, diff_against_baseline, lint_source,
                            load_baseline, save_baseline)
from repro.analysis.baseline import baseline_counts

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(src, path="src/repro/snippet.py"):
    return lint_source(path, textwrap.dedent(src))


def rules_of(findings):
    return [f.rule for f in findings]


# -- HFEL001: unseeded numpy RNG ---------------------------------------------

def test_hfel001_flags_module_level_samplers_and_unseeded_rng():
    bad = lint("""
        import numpy as np
        x = np.random.rand(3)
        rng = np.random.default_rng()
        g = np.random.Generator(np.random.PCG64())
    """)
    assert rules_of(bad).count("HFEL001") >= 3


def test_hfel001_passes_seeded_call_sites():
    good = lint("""
        import numpy as np
        rng = np.random.default_rng(0)
        rng2 = np.random.default_rng(seed=17)
        y = rng.normal(size=3)
    """)
    assert "HFEL001" not in rules_of(good)


# -- HFEL002: time.time for intervals ----------------------------------------

def test_hfel002_flags_time_time_and_passes_perf_counter():
    bad = lint("""
        import time
        t0 = time.time()
        dt = time.time() - t0
    """)
    assert rules_of(bad) == ["HFEL002", "HFEL002"]
    good = lint("""
        import time
        t0 = time.perf_counter()
        dt = time.perf_counter() - t0
    """)
    assert good == []


def test_hfel002_pragma_with_justification_suppresses():
    src = """
        import os, time
        # hfellint: disable=HFEL002 -- wall-clock uniqueness token
        tmp = f".tmp.{os.getpid()}.{int(time.time() * 1e6)}"
    """
    assert lint(src) == []


def test_pragma_without_justification_is_reported_and_suppresses_nothing():
    out = lint("""
        import time
        t0 = time.time()  # hfellint: disable=HFEL002
    """)
    assert sorted(rules_of(out)) == ["HFEL000", "HFEL002"]


# -- HFEL003: host syncs in jitted scopes ------------------------------------

def test_hfel003_flags_host_syncs_on_traced_values():
    bad = lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x, y):
            a = float(x)
            b = y.sum().item()
            c = np.asarray(x + y)
            return a + b + c
    """)
    assert rules_of(bad).count("HFEL003") == 3


def test_hfel003_passes_shape_reads_and_host_code():
    good = lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            n = float(x.shape[0])
            m = len(x)
            return x * n * m

        def host(x):
            return float(x) + np.asarray(x).sum()
    """)
    assert "HFEL003" not in rules_of(good)


def test_hfel003_sees_through_call_form_and_static_argnums():
    bad = lint("""
        import jax

        def local_steps(params, x, n_steps):
            return float(x)

        step = jax.jit(jax.vmap(local_steps), static_argnums=2)
    """)
    assert rules_of(bad) == ["HFEL003"]
    good = lint("""
        import jax

        def local_steps(params, x, n_steps):
            return x * float(n_steps)

        step = jax.jit(jax.vmap(local_steps), static_argnums=2)
    """)
    assert good == []


def test_jit_scope_resolves_shard_map_partial_chain():
    """The assoc_fast idiom: body = partial(impl, **statics), then
    jax.jit(shard_map(body, ...)) — impl is a jitted scope, the partial's
    keywords are static."""
    bad = lint("""
        import jax
        from functools import partial
        from jax.experimental.shard_map import shard_map

        def impl(member, cur, *, axis, kind):
            if cur > 0:
                return member
            return member + 1

        def build(mesh):
            body = partial(impl, axis="i", kind="fast")
            return jax.jit(shard_map(body, mesh=mesh, in_specs=(),
                                     out_specs=()))
    """)
    assert rules_of(bad) == ["HFEL004"]
    good = lint("""
        import jax
        from functools import partial
        from jax.experimental.shard_map import shard_map

        def impl(member, cur, *, axis, kind):
            if kind == "fast":
                return member
            return member + cur

        def build(mesh):
            body = partial(impl, axis="i", kind="fast")
            return jax.jit(shard_map(body, mesh=mesh, in_specs=(),
                                     out_specs=()))
    """)
    assert good == []


# -- HFEL004: trace-time control flow ----------------------------------------

def test_hfel004_flags_branching_on_traced_values():
    bad = lint("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            while x < 3:
                x = x + 1
            for v in x * 2:
                pass
            return x
    """)
    assert rules_of(bad) == ["HFEL004", "HFEL004", "HFEL004"]


def test_hfel004_allows_static_idioms():
    good = lint("""
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("mode",))
        def f(x, buckets, warm=None, *, mode, causal):
            if warm is None:
                x = x + 1
            if mode == "fast":
                x = x * 2
            if causal:
                x = x - 1
            for bd in buckets:
                x = x + bd
            for i in range(len(x)):
                x = x + i
            if x.ndim == 2:
                x = x.sum(0)
            return x
    """)
    assert good == []


# -- HFEL005: float64 creep ---------------------------------------------------

def test_hfel005_flags_float64_in_kernel_files_and_jit_scopes():
    kern = lint("""
        import numpy as np

        def setup():
            return np.zeros(3, dtype=np.float64)
    """, path="src/repro/kernels/fake_kernel.py")
    assert rules_of(kern) == ["HFEL005"]
    jit = lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x.astype("float64")
    """)
    assert rules_of(jit) == ["HFEL005"]


def test_hfel005_allows_host_side_float64_outside_kernels():
    good = lint("""
        import numpy as np

        def finalize(xs):
            return np.asarray(xs, dtype=np.float64).sum()
    """)
    assert good == []


# -- HFEL006: donation on large jitted signatures ----------------------------

def test_hfel006_flags_many_traced_params_without_donation():
    bad = lint("""
        import jax

        @jax.jit
        def sweep(member, assignment, cur, toggles):
            return member, assignment, cur, toggles
    """)
    assert rules_of(bad) == ["HFEL006"]


def test_hfel006_passes_donation_small_signatures_and_statics():
    good = lint("""
        import jax
        from functools import partial

        @partial(jax.jit, donate_argnums=(0, 1))
        def sweep(member, assignment, cur, toggles):
            return member, assignment, cur, toggles

        @jax.jit
        def solve(c, mask):
            return c, mask

        @partial(jax.jit, static_argnames=("kind", "profile"))
        def priced(consts, random_f, *, kind, profile):
            return consts
    """)
    assert "HFEL006" not in rules_of(good)


# -- HFEL007: replicated PRNG keys under shard_map ---------------------------

def test_hfel007_flags_replicated_split_and_fold_in_under_shard_map():
    """The exact hazard the distributed-exchange design dodges: splitting a
    key inside a shard_map'd body advances the SAME stream on every shard
    unless the mesh position is folded in."""
    bad = lint("""
        import jax
        from functools import partial
        from jax.experimental.shard_map import shard_map

        def impl(member, key, *, axis, kind):
            key, sub = jax.random.split(key)
            key2 = jax.random.fold_in(key, 3)
            return member + jax.random.uniform(sub, member.shape)

        def build(mesh):
            body = partial(impl, axis="i", kind="fast")
            return jax.jit(shard_map(body, mesh=mesh, in_specs=(),
                                     out_specs=()))
    """)
    assert rules_of(bad) == ["HFEL007", "HFEL007"]
    # the same split under plain jit (no mesh axis) is NOT a hazard
    plain = lint("""
        import jax

        @jax.jit
        def f(key, x):
            key, sub = jax.random.split(key)
            return x + jax.random.uniform(sub, x.shape)
    """)
    assert plain == []


def test_hfel007_allows_axis_index_folds_and_array_split():
    good = lint("""
        import jax
        from jax import lax
        from functools import partial
        from jax.experimental.shard_map import shard_map
        import jax.numpy as jnp

        def impl(member, key, *, axis):
            # folding the mesh position in diversifies the stream...
            key = jax.random.fold_in(key, lax.axis_index(axis))
            # ...and everything derived from it stays diversified
            key, sub = jax.random.split(key)
            halves = jnp.split(member, 2)       # array split, not the PRNG
            return halves[0] + jax.random.uniform(sub, halves[0].shape)

        def build(mesh):
            body = partial(impl, axis="i")
            return jax.jit(shard_map(body, mesh=mesh, in_specs=(),
                                     out_specs=()))
    """)
    assert good == []


def test_hfel007_pragma_documents_deliberate_replication():
    """The distributed-exchange idiom: the pair proposal is replicated ON
    PURPOSE, and the pragma (with its mandatory justification) records
    that."""
    good = lint("""
        import jax
        from functools import partial
        from jax.experimental.shard_map import shard_map

        def impl(member, key, *, axis):
            # hfellint: disable=HFEL007 -- replicated-key by design
            key, sub = jax.random.split(key)
            return member + jax.random.uniform(sub, member.shape)

        def build(mesh):
            body = partial(impl, axis="i")
            return jax.jit(shard_map(body, mesh=mesh, in_specs=(),
                                     out_specs=()))
    """)
    assert good == []


def test_syntax_error_is_a_finding_not_a_crash():
    out = lint("def broken(:\n    pass\n")
    assert rules_of(out) == ["HFEL000"]


# -- baseline round-trip ------------------------------------------------------

SRC_TWO_VIOLATIONS = """
    import time
    a = time.time()
    b = time.time()
"""


def test_baseline_round_trip_and_diff(tmp_path):
    findings = lint(SRC_TWO_VIOLATIONS)
    assert len(findings) == 2
    path = str(tmp_path / "baseline.json")
    save_baseline(path, findings)
    baseline = load_baseline(path)
    new, stale = diff_against_baseline(findings, baseline)
    assert new == [] and stale == []
    # identical lines share one fingerprint, counted twice
    assert sum(e["count"] for e in baseline.values()) == 2

    # a THIRD identical violation exceeds the baselined count
    findings3 = lint(SRC_TWO_VIOLATIONS + "    c = time.time()\n")
    new, stale = diff_against_baseline(findings3, baseline)
    assert [f.rule for f in new] == ["HFEL002"] and stale == []

    # fixing one makes the baseline entry stale, never a failure
    findings1 = lint("""
        import time
        a = time.time()
    """)
    new, stale = diff_against_baseline(findings1, baseline)
    assert new == [] and len(stale) == 1


def test_fix_baseline_is_idempotent(tmp_path):
    findings = lint(SRC_TWO_VIOLATIONS)
    p1, p2 = str(tmp_path / "b1.json"), str(tmp_path / "b2.json")
    save_baseline(p1, findings)
    save_baseline(p2, findings)
    with open(p1) as f1, open(p2) as f2:
        assert f1.read() == f2.read()
    assert baseline_counts(findings) == load_baseline(p1)


def test_fingerprint_is_line_number_independent():
    a = Finding("HFEL002", "x.py", 10, 4, "m", "t0 = time.time()")
    b = Finding("HFEL002", "x.py", 99, 0, "m", "t0 = time.time()")
    c = Finding("HFEL002", "y.py", 10, 4, "m", "t0 = time.time()")
    assert a.fingerprint() == b.fingerprint() != c.fingerprint()


# -- scripts/lint.py subprocess contract -------------------------------------

def _run_lint(*args):
    return subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "scripts", "lint.py"),
         *args], capture_output=True, text=True, cwd=REPO_ROOT)


def test_lint_script_exits_nonzero_on_seeded_violation(tmp_path):
    viol = tmp_path / "viol.py"
    viol.write_text("import numpy as np\nx = np.random.rand(3)\n")
    baseline = tmp_path / "baseline.json"

    r = _run_lint("--check", "--baseline", str(baseline), str(viol))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "HFEL001" in r.stdout

    # --fix-baseline swallows it; --check then passes and stays idempotent
    r = _run_lint("--fix-baseline", "--baseline", str(baseline), str(viol))
    assert r.returncode == 0, r.stdout + r.stderr
    body = json.loads(baseline.read_text())
    assert sum(e["count"] for e in body["findings"].values()) == 1
    r = _run_lint("--check", "--baseline", str(baseline), str(viol))
    assert r.returncode == 0, r.stdout + r.stderr


@pytest.mark.slow
def test_repo_is_lint_clean_at_head():
    """The tier-1 gate contract: scripts/lint.py --check exits 0 on HEAD
    (slow tier: ~2s of AST parsing, and tier1.sh already runs the gate)."""
    r = _run_lint("--check")
    assert r.returncode == 0, r.stdout + r.stderr
