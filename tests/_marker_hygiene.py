"""Marker hygiene: any test that runs longer than the configured limit must
carry ``@pytest.mark.slow``, so ``scripts/tier1.sh --fast`` keeps meaning
"fast" as the suite grows.

Enforcement is opt-in via the ``TIER1_SLOW_MARKER_LIMIT_S`` environment
variable (seconds; unset/0 disables), which ``scripts/tier1.sh`` exports —
plain local ``pytest`` runs are never failed by a loaded machine. The hook
lives in its own importable module (conftest re-exports it) so the
enforcement path itself is testable in a pytest subprocess.
"""

import os

import pytest

ENV_VAR = "TIER1_SLOW_MARKER_LIMIT_S"


def slow_marker_limit_s() -> float:
    try:
        return float(os.environ.get(ENV_VAR, "") or 0.0)
    except ValueError:
        return 0.0


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    report = outcome.get_result()
    limit = slow_marker_limit_s()
    if limit <= 0 or item.get_closest_marker("slow") is not None:
        return
    # setup time counts too: an expensive (module-scoped) fixture bills its
    # build to the first test that triggers it, which is exactly where the
    # wall-clock creep lives
    if report.when == "setup" and report.passed:
        item._hygiene_setup_s = report.duration
        return
    if report.when == "call" and report.passed:
        total = report.duration + getattr(item, "_hygiene_setup_s", 0.0)
        if total > limit:
            report.outcome = "failed"
            report.longrepr = (
                f"marker hygiene: {item.nodeid} took {total:.1f}s "
                f"setup+call (> {ENV_VAR}={limit:g}s) without "
                "@pytest.mark.slow — mark it slow (scripts/tier1.sh --fast "
                "deselects it) or make it fast")
