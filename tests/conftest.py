import os
import sys

# Smoke tests and benches run single-device (the 512-device override lives
# ONLY in repro.launch.dryrun, which runs as its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

sys.path.insert(0, os.path.dirname(__file__))
# marker hygiene: over-limit unmarked tests FAIL when scripts/tier1.sh
# exports TIER1_SLOW_MARKER_LIMIT_S (see tests/_marker_hygiene.py)
from _marker_hygiene import pytest_runtest_makereport  # noqa: E402,F401

jax.config.update("jax_enable_x64", False)

# Tests already failing in the seed snapshot (v0) get tagged with the
# ``seed_known_failure`` marker so ``scripts/tier1.sh`` (which runs
# ``-m "not seed_known_failure"``) keeps a meaningful green/red signal.
# The original 14 entries (flash-attention kernel sweeps, small-mesh
# launch smoke tests, the end-to-end LM loop) were jax-version
# incompatibilities, fixed in PR 3 (pltpu.TPUCompilerParams,
# jax.tree_util.tree_flatten_with_path, ``with mesh:``), so the set is now
# empty and tier-1 runs the full suite. The plumbing stays for any future
# genuinely environment-bound straggler — add its nodeid here WITH a
# comment saying what environment limitation it needs.
SEED_KNOWN_FAILURES: frozenset[str] = frozenset()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "seed_known_failure: test already failing in the seed snapshot; "
        "excluded by scripts/tier1.sh so tier-1 green/red is meaningful")
    config.addinivalue_line(
        "markers",
        "slow: multi-minute test (launch/serve smoke tests, large "
        "association convergence runs); deselected by scripts/tier1.sh "
        "--fast")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.nodeid in SEED_KNOWN_FAILURES:
            item.add_marker(pytest.mark.seed_known_failure)


@pytest.fixture
def compile_log():
    """One jax-compile event recorder per test (repro.analysis.recompile):
    ``jax_log_compiles`` is enabled for the test's duration and every real
    XLA compilation appends the compiled function's name to ``.events`` —
    cache hits append nothing. Backs the recompilation-sentinel tier
    (tests/test_recompile_sentinel.py)."""
    from repro.analysis.recompile import CompileLog

    with CompileLog() as log:
        yield log
