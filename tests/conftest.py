import os

# Smoke tests and benches run single-device (the 512-device override lives
# ONLY in repro.launch.dryrun, which runs as its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
