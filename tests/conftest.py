import os

# Smoke tests and benches run single-device (the 512-device override lives
# ONLY in repro.launch.dryrun, which runs as its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Tests that already failed in the seed snapshot (v0) of this repo — kernel
# sweeps, small-mesh launch smoke tests, and the end-to-end LM loop (the
# last one is flaky at seed: it fails most runs but occasionally passes).
# They are tagged with the ``seed_known_failure`` marker so that
# ``scripts/tier1.sh`` (which runs ``-m "not seed_known_failure"``) gives a
# meaningful green/red signal for everything this repo's PRs actually touch.
# Fixing any of these should REMOVE its id here, not keep the mark.
SEED_KNOWN_FAILURES = frozenset({
    "tests/test_kernels.py::test_flash_attention_sweep[float32-shape0-True-blocks0]",
    "tests/test_kernels.py::test_flash_attention_sweep[float32-shape1-True-blocks1]",
    "tests/test_kernels.py::test_flash_attention_sweep[float32-shape2-False-blocks2]",
    "tests/test_kernels.py::test_flash_attention_sweep[float32-shape3-True-blocks3]",
    "tests/test_kernels.py::test_flash_attention_sweep[bfloat16-shape0-True-blocks0]",
    "tests/test_kernels.py::test_flash_attention_sweep[bfloat16-shape1-True-blocks1]",
    "tests/test_kernels.py::test_flash_attention_sweep[bfloat16-shape2-False-blocks2]",
    "tests/test_kernels.py::test_flash_attention_sweep[bfloat16-shape3-True-blocks3]",
    "tests/test_kernels.py::test_flash_attention_gqa",
    "tests/test_kernels.py::test_flash_attention_vjp_matches_ref",
    "tests/test_launch.py::test_train_sync_small_mesh",
    "tests/test_launch.py::test_train_hierarchical_small_mesh",
    "tests/test_launch.py::test_serve_small_mesh",
    "tests/test_system.py::test_end_to_end_lm_training_loop",
})


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "seed_known_failure: test already failing in the seed snapshot; "
        "excluded by scripts/tier1.sh so tier-1 green/red is meaningful")
    config.addinivalue_line(
        "markers", "slow: long-running launch/serve smoke test")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.nodeid in SEED_KNOWN_FAILURES:
            item.add_marker(pytest.mark.seed_known_failure)
