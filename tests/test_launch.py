"""Launcher integration tests (subprocess: each needs its own jax device
count, set via XLA_FLAGS before init)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, n_devices: int, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run([sys.executable, "-m"] + args, cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_sync_small_mesh():
    r = _run(["repro.launch.train", "--arch", "qwen3-0.6b", "--reduced",
              "--devices", "2x2", "--steps", "4", "--ckpt-every", "1000",
              "--shape", "train_4k"], n_devices=4)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "step     3" in r.stdout or "step 3" in r.stdout.replace("  ", " ")


@pytest.mark.slow
def test_train_hierarchical_small_mesh():
    r = _run(["repro.launch.train", "--arch", "olmo-1b", "--reduced",
              "--devices", "2x2x1", "--mode", "hierarchical",
              "--edge-period", "2", "--steps", "4", "--ckpt-every", "1000",
              "--shape", "train_4k"], n_devices=4)
    assert r.returncode == 0, r.stderr[-2000:]


@pytest.mark.slow
def test_serve_small_mesh():
    r = _run(["repro.launch.serve", "--arch", "qwen3-0.6b", "--reduced",
              "--devices", "2x2", "--new-tokens", "4"], n_devices=4)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout
