"""Optimizers, schedules, compression, checkpointing, data pipeline,
hierarchy schedule, fault tolerance."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.core import SyncLevel, SyncSchedule, make_scenario
from repro.core.compression import Int8Compressor, TopKCompressor
from repro.data import TokenPipeline, make_mnist_like, partition_power_law
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd
from repro.optim.schedules import cosine_decay, linear_warmup_cosine
from repro.runtime import (ElasticReassociator, FailureInjector,
                           StragglerPolicy, retry_with_backoff)
from repro.utils import tree_global_norm


# ------------------------------ optimizers -------------------------------

@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1), lambda: sgd(0.1, momentum=0.9),
    lambda: adamw(0.05), lambda: clip_by_global_norm(adamw(0.05), 1.0)])
def test_optimizers_descend_quadratic(make_opt):
    opt = make_opt()
    params = {"x": jnp.asarray([3.0, -2.0]), "y": jnp.asarray([[1.5]])}
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(p))

    start = float(loss(params))
    # Adam moves ~lr per step on a quadratic, so give it enough steps to
    # traverse |x0| = 3 and settle
    for step in range(150):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, step)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.05 * start


def test_clipping_caps_update_norm():
    opt = clip_by_global_norm(sgd(1.0), 0.5)
    params = {"x": jnp.zeros(3)}
    g = {"x": jnp.asarray([100.0, 0.0, 0.0])}
    upd, _ = opt.update(g, opt.init(params), params, 0)
    assert float(tree_global_norm(upd)) <= 0.5 + 1e-5


def test_schedules_shapes():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < 0.1
    c = cosine_decay(2.0, 50, floor=0.5)
    assert abs(float(c(0)) - 2.0) < 1e-6
    assert abs(float(c(50)) - 0.5) < 1e-6


# ------------------------------ compression ------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), ratio=st.floats(0.01, 0.5))
def test_topk_error_feedback_is_lossless_in_total(seed, ratio):
    """kept + residual == update + old_residual exactly (error feedback)."""
    rng = np.random.default_rng(seed)
    upd = {"a": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    comp = TopKCompressor(ratio=ratio)
    state = {"a": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    kept, resid = comp.compress(upd, state)
    np.testing.assert_allclose(np.asarray(kept["a"] + resid["a"]),
                               np.asarray(upd["a"] + state["a"]), atol=1e-6)
    k = max(int(64 * ratio), 1)
    assert int((np.asarray(kept["a"]) != 0).sum()) <= k + 1


def test_int8_quantization_error_bounded():
    x = {"w": jnp.linspace(-3.0, 3.0, 101)}
    comp = Int8Compressor()
    y, _ = comp.compress(x, ())
    err = float(jnp.max(jnp.abs(y["w"] - x["w"])))
    assert err <= 3.0 / 127.0 + 1e-6
    assert comp.wire_bytes(x) < 4 * 101


# ------------------------------ checkpointing ------------------------------

def test_checkpoint_roundtrip_and_gc():
    tree = {"layer": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step_count": jnp.asarray(7)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        for s in (10, 20, 30):
            mgr.save(s, tree, extras={"lr": 0.1})
        steps = sorted(int(p.split("_")[1]) for p in os.listdir(d))
        assert steps == [20, 30], "keep-last-2 GC"
        step, restored, extras = mgr.restore(template=tree)
        assert step == 30 and extras == {"lr": 0.1}
        np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                      np.asarray(tree["layer"]["w"]))


def test_checkpoint_atomicity_tmp_cleanup():
    tree = {"w": jnp.ones(4)}
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 1, tree)
        assert os.path.basename(path) == "step_0000000001"
        assert not any("tmp" in p for p in os.listdir(d))
        # overwrite same step is atomic
        save_checkpoint(d, 1, {"w": jnp.zeros(4)})
        _, restored, _ = load_checkpoint(d, template=tree)
        assert float(restored["w"].sum()) == 0.0


# ------------------------------ data ------------------------------

def test_partition_power_law_properties():
    sizes = partition_power_law(10_000, 50,
                                rng=np.random.default_rng(0))
    assert len(sizes) == 50 and sizes.min() >= 20
    assert sizes.max() > 2 * np.median(sizes), "heavy tail expected"


def test_mnist_like_label_restriction():
    ds = make_mnist_like(10, seed=0)
    for c in range(10):
        labels = set(np.unique(ds.client_y[c])) - {-1}
        assert len(labels) <= 2, "paper: 2 labels per device"


def test_token_pipeline_host_sharding_and_determinism():
    a = next(TokenPipeline(100, 16, 8, seed=1, process_index=0,
                           process_count=2))
    b = next(TokenPipeline(100, 16, 8, seed=1, process_index=1,
                           process_count=2))
    a2 = next(TokenPipeline(100, 16, 8, seed=1, process_index=0,
                            process_count=2))
    assert a.shape == (4, 17)
    assert not np.array_equal(a, b), "hosts must get different slices"
    np.testing.assert_array_equal(a, a2)


# ------------------------------ hierarchy ------------------------------

def test_sync_schedule_algorithm1_structure():
    sched = SyncSchedule(local_iters=3, edge_iters=2)
    levels = [sched.level(s) for s in range(12)]
    # t % L == 0 -> edge; t % (L*I) == 0 -> cloud (1-based t)
    assert levels[2] == SyncLevel.EDGE
    assert levels[5] == SyncLevel.CLOUD
    assert levels[0] == SyncLevel.LOCAL
    arr = np.asarray(sched.level_array(12))
    assert list(arr) == [int(l) for l in levels]
    assert (arr == int(SyncLevel.CLOUD)).sum() == 2


# ------------------------------ fault tolerance ------------------------------

def test_straggler_policy_and_min_participants():
    sp = StragglerPolicy(deadline=1.0, slack=1.2, min_participants=2)
    times = np.asarray([5.0, 6.0, 7.0])
    mask = sp.mask(times)
    assert mask.sum() == 2, "keeps the fastest min_participants"


def test_failure_injector_deterministic():
    a = FailureInjector(10, p_fail=0.5, seed=7)
    b = FailureInjector(10, p_fail=0.5, seed=7)
    np.testing.assert_array_equal(a.step(), b.step())


def test_elastic_reassociation_never_assigns_dead_to_live_groups():
    sc = make_scenario(12, 3, seed=0)
    er = ElasticReassociator(sc, seed=0)
    er.initial()
    alive = np.ones(12, bool)
    alive[[2, 5]] = False
    res = er.on_membership_change(alive)
    assert len(res.assignment) == 12
    assert np.isfinite(res.total_cost)


def test_retry_with_backoff():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_with_backoff(flaky, sleep=lambda _: None) == "ok"
    assert len(calls) == 3
    with pytest.raises(RuntimeError):
        retry_with_backoff(lambda: (_ for _ in ()).throw(RuntimeError("x")),
                           max_attempts=2, sleep=lambda _: None)
