"""Section III solvers: feasibility, KKT structure, optimality cross-checks.

Property tests draw random problem instances (Table II ranges) and assert
the invariants every solver must satisfy plus mutual consistency between
the paper-faithful solver, the exact solver and the subgradient oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import make_scenario
from repro.core.cost_model import LearningParams, ra_constants, ra_objective
from repro.core import resource_allocation as ra


def _instance(seed: int, n_active: int, n_total: int = 16,
              lambda_t: float = 0.5):
    lp = LearningParams(lambda_e=1.0 - lambda_t, lambda_t=lambda_t)
    sc = make_scenario(n_total, 3, seed=seed, lp=lp)
    c = ra_constants(sc.dev, sc.srv.bandwidth[0], sc.srv.noise[0], sc.lp)
    mask = jnp.arange(n_total) < n_active
    return c, mask


def _check_feasible(c, mask, sol):
    beta = np.asarray(sol.beta)
    f = np.asarray(sol.f)
    m = np.asarray(mask)
    assert np.all(beta[m] > 0), "active betas must be positive"
    assert np.all(beta[~m] == 0), "padded betas must be zero"
    assert beta.sum() <= 1.0 + 1e-4, f"sum beta = {beta.sum()}"
    assert np.all(f[m] >= np.asarray(c.f_min)[m] * (1 - 1e-5))
    assert np.all(f[m] <= np.asarray(c.f_max)[m] * (1 + 1e-5))


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), n_active=st.integers(1, 16),
       lambda_t=st.floats(0.05, 0.95))
def test_solvers_feasible_and_ordered(seed, n_active, lambda_t):
    c, mask = _instance(seed, n_active, lambda_t=lambda_t)
    exact = ra.solve_exact(c, mask)
    fp = ra.solve_fixed_point(c, mask)
    paper = ra.solve_paper(c, mask)
    for sol in (exact, fp, paper):
        _check_feasible(c, mask, sol)
        assert np.isfinite(float(sol.cost))
    # the exact solver must not be beaten by the approximate ones
    assert float(exact.cost) <= float(fp.cost) * 1.01
    assert float(exact.cost) <= float(paper.cost) * 1.01


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), n_active=st.integers(2, 12))
def test_exact_matches_subgradient_oracle(seed, n_active):
    c, mask = _instance(seed, n_active)
    exact = ra.solve_exact(c, mask)
    oracle = ra.solve_reference(c, mask)
    # within 2% of the structure-free oracle (subgradient is itself approx)
    assert float(exact.cost) <= float(oracle.cost) * 1.02


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n_active=st.integers(2, 12))
def test_perturbation_optimality(seed, n_active):
    """No random feasible perturbation of the exact solution improves it."""
    c, mask = _instance(seed, n_active)
    sol = ra.solve_exact(c, mask)
    rng = np.random.default_rng(seed)
    base = float(sol.cost)
    beta = np.asarray(sol.beta)
    f = np.asarray(sol.f)
    m = np.asarray(mask)
    for _ in range(8):
        db = rng.normal(0, 0.02, beta.shape) * m
        nb = np.clip(beta + db, 1e-6, 1.0) * m
        nb = nb / max(nb.sum(), 1.0)  # keep sum <= 1
        nf = np.clip(f * (1 + rng.normal(0, 0.05, f.shape)),
                     np.asarray(c.f_min), np.asarray(c.f_max))
        safe_beta = jnp.where(mask, jnp.maximum(jnp.asarray(nb), 1e-12), 1.0)
        cost = float(ra_objective(c, mask, jnp.asarray(nf), safe_beta))
        assert cost >= base * (1 - 5e-3), (cost, base)


def test_beta_rule_eq19_normalization():
    c, mask = _instance(0, 8)
    f = jnp.sqrt(c.f_min * c.f_max)
    beta = ra.beta_of_f(c, mask, f)
    assert abs(float(beta.sum()) - 1.0) < 1e-5
    # proportionality: beta ratios match cube-root score ratios
    tau = 2 * c.b * f**3 / c.e
    score = jnp.cbrt(c.a + tau * c.d)
    ratio = np.asarray(beta)[:8] / np.asarray(score)[:8]
    assert np.allclose(ratio, ratio[0], rtol=1e-4)


def test_common_deadline_structure():
    """KKT: devices with interior f finish at the same time t* (eq. 25)."""
    c, mask = _instance(3, 10)
    sol = ra.solve_exact(c, mask)
    m = np.asarray(mask)
    f = np.asarray(sol.f)
    beta = np.maximum(np.asarray(sol.beta), 1e-12)
    finish = np.asarray(c.d) / beta + np.asarray(c.e) / f
    interior = m & (f > np.asarray(c.f_min) * 1.001) \
        & (f < np.asarray(c.f_max) * 0.999)
    if interior.sum() >= 2:
        times = finish[interior]
        assert times.max() / times.min() < 1.05, times


def test_partial_optimizers_are_worse_or_equal():
    """comp-only / comm-only optimization can't beat the joint optimum."""
    c, mask = _instance(1, 8)
    joint = float(ra.solve_exact(c, mask).cost)
    n_active = int(mask.sum())
    uniform = jnp.where(mask, 1.0 / n_active, 0.0)
    comp = float(ra.optimize_f_given_beta(c, mask, uniform).cost)
    f_rand = jnp.asarray(np.random.default_rng(0).uniform(
        np.asarray(c.f_min), np.asarray(c.f_max)).astype(np.float32))
    comm = float(ra.optimize_beta_given_f(c, mask, f_rand).cost)
    assert joint <= comp * 1.01
    assert joint <= comm * 1.01


def test_empty_group_zero_cost():
    c, mask = _instance(0, 0)
    for solver in (ra.solve_exact, ra.solve_fixed_point, ra.solve_paper):
        assert float(solver(c, jnp.zeros(16, bool)).cost) == 0.0
