"""Dynamic scenarios: seeded perturbations (`perturb_scenario`), incremental
reach-map maintenance (`update_reach_index` / `update_reach_buckets`), and
the fast engine's warm-started `rerun_incremental` parity with a cold
rebuild."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import make_scenario, perturb_scenario
from repro.core.assoc_fast import FastAssociationEngine
from repro.core.scenario import (reach_index_map, update_reach_buckets,
                                 update_reach_index)

CHURN = dict(drift_m=80.0, move_frac=0.2, flip_frac=0.1, depart_frac=0.15)


# ---------------------------------------------------------------------------
# perturb_scenario
# ---------------------------------------------------------------------------

def test_perturb_deterministic_and_pure():
    sc = make_scenario(20, 4, seed=1, reach_m=300.0)
    avail0 = sc.avail.copy()
    a, da = perturb_scenario(sc, seed=7, **CHURN)
    b, db = perturb_scenario(sc, seed=7, **CHURN)
    np.testing.assert_array_equal(a.dist, b.dist)
    np.testing.assert_array_equal(a.avail, b.avail)
    np.testing.assert_array_equal(a.active_mask, b.active_mask)
    np.testing.assert_array_equal(da.stale_servers, db.stale_servers)
    np.testing.assert_array_equal(da.moved, db.moved)
    # the input scenario is untouched
    np.testing.assert_array_equal(sc.avail, avail0)
    assert sc.active is None
    # a different seed perturbs differently
    c, _ = perturb_scenario(sc, seed=8, **CHURN)
    assert not (np.array_equal(a.dist, c.dist)
                and np.array_equal(a.active_mask, c.active_mask))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_active_devices_always_reach_a_server(seed):
    """Constraint (17e) must survive ANY delta: every active device keeps at
    least one effectively reachable server, even under heavy simultaneous
    drift + flips + departures, and across chained perturbations."""
    sc = make_scenario(24, 5, seed=seed, reach_m=250.0)
    for step in range(4):
        sc, delta = perturb_scenario(
            sc, seed=100 * seed + step, drift_m=120.0, move_frac=0.4,
            flip_frac=0.3, depart_frac=0.2, arrive_frac=0.5)
        eff = sc.eff_avail
        act = sc.active_mask
        assert eff.any(axis=0)[act].all()
        # the maps the engine builds from this must therefore exist
        reach_index_map(sc.avail, active=act)
        reach_index_map(sc.avail, bucketed=True, active=act)
        # delta bookkeeping is self-consistent
        assert not (delta.arrived & delta.departed).any()
        assert (delta.stale_servers | ~delta.eff_flips.any(axis=1)).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100_000), drift=st.floats(0.0, 150.0),
       flip=st.floats(0.0, 0.5))
def test_every_device_keeps_raw_reach_after_perturb(seed, drift, flip):
    """The 17e repair covers EVERY device, not just the active ones: an
    inactive device whose reach the flips wiped out used to come back with
    an all-``False`` column, and its later re-arrival silently landed on
    server 0 via the masked argmin. Now the perturbation itself restores
    the nearest server, so raw reach is a scenario-wide invariant."""
    sc = make_scenario(20, 4, seed=3, reach_m=220.0)
    for step in range(3):
        sc, _ = perturb_scenario(
            sc, seed=seed + step, drift_m=drift, move_frac=0.3,
            flip_frac=flip, depart_frac=0.3, arrive_frac=0.4)
        assert sc.avail.any(axis=0).all(), (
            "a device lost its last raw-reachable server after perturb")
        # active devices additionally keep EFFECTIVE reach (17e proper)
        assert sc.eff_avail.any(axis=0)[sc.active_mask].all()


def test_perturb_holds_device_params_fixed():
    """Cost-model constants must be delta-invariant (the incremental cache
    contract): only dist/avail/active may change, and untouched dist
    columns stay bit-identical."""
    sc = make_scenario(20, 4, seed=2, reach_m=300.0)
    sc2, delta = perturb_scenario(sc, seed=9, **CHURN)
    assert sc2.dev is sc.dev and sc2.srv is sc.srv and sc2.lp is sc.lp
    unmoved = ~delta.moved
    np.testing.assert_array_equal(sc.dist[:, unmoved], sc2.dist[:, unmoved])
    assert (sc.dist[:, delta.moved] != sc2.dist[:, delta.moved]).any()
    np.testing.assert_array_equal(delta.avail_flips, sc.avail != sc2.avail)


def test_perturb_requires_positions():
    sc = make_scenario(8, 2, seed=0)
    sc.dev_xy = None
    with pytest.raises(ValueError, match="positions"):
        perturb_scenario(sc, seed=0)


# ---------------------------------------------------------------------------
# incremental reach maps
# ---------------------------------------------------------------------------

def _assert_flat_consistent(ri, eff):
    k, n = eff.shape
    for s in range(k):
        reach = np.flatnonzero(eff[s])
        np.testing.assert_array_equal(ri.idx[s, ri.valid[s]], reach)
        np.testing.assert_array_equal(ri.slot[s, reach],
                                      np.arange(reach.size))
        assert (ri.slot[s, ~eff[s]] == ri.r_max).all()


def _assert_buckets_consistent(rbk, eff):
    k, n = eff.shape
    seen = np.zeros(k, dtype=int)
    for b, bucket in enumerate(rbk.buckets):
        assert bucket.width <= rbk.r_max
        for row, srv in enumerate(bucket.servers):
            seen[srv] += 1
            assert rbk.bucket_of[srv] == b and rbk.row_of[srv] == row
            reach = np.flatnonzero(eff[srv])
            assert reach.size <= bucket.width
            assert bucket.valid[row, :reach.size].all()
            assert not bucket.valid[row, reach.size:].any()
            np.testing.assert_array_equal(bucket.idx[row, :reach.size],
                                          reach)
            np.testing.assert_array_equal(rbk.slot[srv, reach],
                                          np.arange(reach.size))
            # the sentinel must be rejected by every bucket's slot test
            assert (rbk.slot[srv, ~eff[srv]] >= bucket.width).all()
            assert bucket.key == max(reach.size - 1, 0).bit_length()
    assert (seen == 1).all(), "buckets must partition the servers"


def test_update_reach_index_patch_and_rebuild():
    sc = make_scenario(20, 4, seed=3, reach_m=300.0)
    ri = reach_index_map(sc.avail)
    sc2, delta = perturb_scenario(sc, seed=11, **CHURN)
    ri2, rebuilt = update_reach_index(ri, sc2.avail,
                                      active=sc2.active_mask,
                                      changed_servers=delta.stale_servers)
    _assert_flat_consistent(ri2, sc2.eff_avail)
    # shrinking reach never rebuilds (the allocated width is kept) ...
    if not rebuilt:
        assert ri2.r_max == ri.r_max
    # ... and growth past the allocated width rebuilds from scratch
    avail = sc.avail.copy()
    avail[0, :] = True                      # server 0 now reaches everyone
    ri3, rebuilt3 = update_reach_index(ri, avail)
    assert rebuilt3 and ri3.r_max == sc.n_devices
    _assert_flat_consistent(ri3, avail)


def test_update_reach_buckets_patch_keeps_untouched_arrays():
    """A within-bucket count change patches rows; buckets the delta never
    touches keep their arrays object-identical (that is what preserves the
    compiled sweep shapes and cached toggle rows across small deltas)."""
    # synthetic reach: counts 4 / 8 / 16 -> binary keys 2 / 3 / 4
    avail = np.zeros((3, 16), dtype=bool)
    avail[0, :4] = True
    avail[1, :8] = True
    avail[2, :] = True
    rbk = reach_index_map(avail, bucketed=True)
    assert [b.key for b in rbk.buckets] == [2, 3, 4]
    # server 1: 8 -> 7 stays inside key 3 and width 8 -> pure row patch
    avail2 = avail.copy()
    avail2[1, 7] = False
    rbk2, carry = update_reach_buckets(rbk, avail2)
    assert carry == [0, 1, 2]
    _assert_buckets_consistent(rbk2, avail2)
    assert rbk2.buckets[0].idx is rbk.buckets[0].idx     # untouched
    assert rbk2.buckets[2].idx is rbk.buckets[2].idx     # untouched
    assert rbk2.buckets[1].idx is not rbk.buckets[1].idx  # patched copy
    assert rbk2.buckets[1].width == rbk.buckets[1].width


def test_update_reach_buckets_overflow_rebuilds_only_crossed_buckets():
    """Crossing a binary bucket boundary (key change) rebuilds exactly the
    buckets the server leaves and joins; the result matches a from-scratch
    rebuild semantically (and here bit-identically, since the rebuilt
    widths coincide)."""
    avail = np.zeros((3, 16), dtype=bool)
    avail[0, :4] = True
    avail[1, :8] = True
    avail[2, :] = True
    rbk = reach_index_map(avail, bucketed=True)
    # server 0: 4 -> 6 crosses key 2 -> 3; bucket key2 empties (dropped),
    # bucket key3 absorbs server 0; bucket key4 must be untouched
    avail2 = avail.copy()
    avail2[0, 4:6] = True
    rbk2, carry = update_reach_buckets(rbk, avail2)
    _assert_buckets_consistent(rbk2, avail2)
    assert carry == [None, 2]
    assert rbk2.buckets[1].idx is rbk.buckets[2].idx
    fresh = reach_index_map(avail2, bucketed=True)
    assert len(rbk2.buckets) == len(fresh.buckets)
    for inc, ref in zip(rbk2.buckets, fresh.buckets):
        np.testing.assert_array_equal(inc.servers, ref.servers)
        np.testing.assert_array_equal(inc.idx, ref.idx)
        np.testing.assert_array_equal(inc.valid, ref.valid)
        assert (inc.width, inc.key) == (ref.width, ref.key)
    np.testing.assert_array_equal(rbk2.bucket_of, fresh.bucket_of)
    np.testing.assert_array_equal(rbk2.row_of, fresh.row_of)
    np.testing.assert_array_equal(rbk2.slot, fresh.slot)


def test_update_reach_buckets_sentinel_grows_monotonically():
    """When the widest bucket overflows, the shared out-of-reach sentinel
    grows and every stale sentinel entry is remapped — `slot < width` must
    stay a sound validity test for all servers."""
    avail = np.zeros((3, 16), dtype=bool)
    avail[0, :4] = True
    avail[1, :8] = True
    avail[1, 12:] = True  # keep every device reachable somewhere
    avail[2, :12] = True
    rbk = reach_index_map(avail, bucketed=True)
    assert rbk.r_max == 12
    avail2 = avail.copy()
    avail2[2, :] = True   # server 2: 16 devices, past the old r_max
    rbk2, _ = update_reach_buckets(rbk, avail2)
    assert rbk2.r_max == 16 > rbk.r_max
    _assert_buckets_consistent(rbk2, avail2)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_buckets_match_rebuilt_under_churn(seed):
    """Chained perturbations: the incrementally maintained maps must stay
    semantically identical to from-scratch maps of every perturbed state."""
    sc = make_scenario(24, 5, seed=seed, reach_m=250.0)
    rbk = reach_index_map(sc.avail, bucketed=True)
    ri = reach_index_map(sc.avail)
    for step in range(3):
        sc, delta = perturb_scenario(
            sc, seed=10 * seed + step, drift_m=120.0, move_frac=0.3,
            flip_frac=0.2, depart_frac=0.15, arrive_frac=0.3)
        act = sc.active_mask
        rbk, _ = update_reach_buckets(rbk, sc.avail, active=act,
                                      changed_servers=delta.stale_servers)
        ri, rebuilt = update_reach_index(ri, sc.avail, active=act,
                                         changed_servers=delta.stale_servers)
        eff = sc.eff_avail
        _assert_buckets_consistent(rbk, eff)
        _assert_flat_consistent(ri, eff)


# ---------------------------------------------------------------------------
# warm-started rerun_incremental vs cold rebuild
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compact", [False, True, "bucketed"])
def test_rerun_incremental_matches_cold_rebuild(compact):
    """The hard parity gate: the warm-started stable point must be
    bit-identical to a cold rebuild descending from the same repaired
    assignment (verify=True raises otherwise), in every sweep space."""
    sc = make_scenario(18, 4, seed=0, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact=compact)
    eng.run("nearest", exchange_samples=0)
    sc2, delta = perturb_scenario(sc, seed=5, **CHURN)
    warm = eng.rerun_incremental(sc2, delta, exchange_samples=0, verify=True)
    # the warm stable point is genuinely stable: rerunning applies nothing
    again = FastAssociationEngine(sc2, kind="fast", seed=0,
                                  compact=compact).run(
        assignment=warm.assignment, exchange_samples=0)
    assert again.n_adjustments == 0
    # and every active device sits within effective reach
    eff = sc2.eff_avail
    for dev in np.flatnonzero(sc2.active_mask):
        assert eff[warm.assignment[dev], dev]


@pytest.mark.slow
def test_rerun_incremental_chained_with_arrivals():
    sc = make_scenario(18, 4, seed=1, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact=True)
    eng.run("nearest", exchange_samples=0)
    sc1, d1 = perturb_scenario(sc, seed=2, drift_m=80.0, move_frac=0.2,
                               depart_frac=0.3)
    assert d1.departed.sum() > 0
    r1 = eng.rerun_incremental(sc1, d1, exchange_samples=0, verify=True)
    # departed devices are in no group and carry no resources
    inact = np.flatnonzero(~sc1.active_mask)
    assert inact.size and (r1.f[inact] == 0).all()
    assert (r1.beta[inact] == 0).all()
    sc2, d2 = perturb_scenario(sc1, seed=3, drift_m=80.0, move_frac=0.2,
                               arrive_frac=1.0)
    assert d2.arrived.sum() > 0
    r2 = eng.rerun_incremental(sc2, d2, exchange_samples=0, verify=True)
    assert sc2.active_mask.all()
    assert (r2.f > 0).all()


@pytest.mark.slow
def test_rerun_incremental_after_tiered_run():
    sc = make_scenario(16, 4, seed=2, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, compact=True)
    eng.run_tiered("nearest", exchange_samples=0)
    sc2, delta = perturb_scenario(sc, seed=4, **CHURN)
    res = eng.rerun_incremental(sc2, delta, exchange_samples=0, verify=True)
    assert np.isfinite(res.total_cost) and res.total_cost > 0


def test_rerun_incremental_requires_prior_run():
    sc = make_scenario(10, 3, seed=0, reach_m=300.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0)
    sc2, delta = perturb_scenario(sc, seed=1, move_frac=0.2)
    with pytest.raises(RuntimeError, match="prior run"):
        eng.rerun_incremental(sc2, delta)


def test_reference_engine_active_parity_on_churn_scenario():
    """The host reference engine must honour the active mask exactly like
    the fast engine: inactive devices in no group, zero resources, and the
    deterministic steepest-descent stable points must coincide."""
    from repro.core.edge_association import AssociationEngine
    sc = make_scenario(14, 3, seed=4, reach_m=300.0)
    sc1, _ = perturb_scenario(sc, seed=2, move_frac=0.0, depart_frac=0.25)
    dead = np.flatnonzero(~sc1.active_mask)
    assert dead.size > 0
    ref = AssociationEngine(sc1, kind="fast", seed=0).run_batched(
        "nearest", exchange_samples=0)
    fast = FastAssociationEngine(sc1, kind="fast", seed=0).run(
        "nearest", exchange_samples=0)
    assert np.array_equal(ref.assignment, fast.assignment)
    assert abs(ref.total_cost - fast.total_cost) <= 1e-4 * fast.total_cost
    assert (ref.f[dead] == 0).all() and (ref.beta[dead] == 0).all()
    assert np.isfinite(ref.true_cost)


@pytest.mark.slow
def test_exchanges_never_move_inactive_and_respect_binding_caps():
    """PR-10 satellite regression. ``do_exchange`` samples device pairs
    uniformly from ``[0, n)`` with no explicit active gate — the only thing
    standing between a parked device and an escape move is ``can_join``'s
    ``ex_bucket.ok`` mask, which is derived from ``eff_avail`` (active-
    masked) in every sweep space. Pin that, plus cap-neutrality: exchanges
    are 1-for-1 swaps, so per-server loads are unchanged by construction and
    a binding ``capacity`` can never be violated by the escape path.

    Transfers only ever move active devices (sweep rows are active-masked
    at bucket build time) and both runs share the same init, so any
    divergence at an inactive index would implicate an exchange."""
    sc = make_scenario(16, 4, seed=1, reach_m=300.0, cap_slack=1.2)
    sc1, _ = perturb_scenario(sc, seed=2, move_frac=0.0, depart_frac=0.25)
    dead = np.flatnonzero(~sc1.active_mask)
    assert dead.size > 0 and sc1.capacity is not None

    def cold(samples):
        return FastAssociationEngine(
            sc1, kind="fast", seed=0, compact="bucketed").run(
            "nearest", exchange_samples=samples)

    no_ex, ex = cold(0), cold(64)
    assert ex.n_adjustments > no_ex.n_adjustments  # escape path fired
    np.testing.assert_array_equal(ex.assignment[dead],
                                  no_ex.assignment[dead])
    load = np.bincount(ex.assignment[sc1.active_mask],
                       minlength=sc1.n_servers)
    assert (load <= sc1.capacity).all()
    assert (load == sc1.capacity).any()  # the caps genuinely bind

    # the churn-tick warm path carries the same contract: identical prior
    # engines, one rerun transfer-only and one with exchanges, both under
    # the verify (cold-rebuild parity) gate
    sc2, d2 = perturb_scenario(sc1, seed=3, drift_m=60.0, move_frac=0.2,
                               flip_frac=0.1, depart_frac=0.15,
                               arrive_frac=0.3)
    dead2 = np.flatnonzero(~sc2.active_mask)
    assert dead2.size > 0
    warms = []
    for samples in (0, 64):
        eng = FastAssociationEngine(sc1, kind="fast", seed=0,
                                    compact="bucketed")
        eng.run("nearest", exchange_samples=64)
        warms.append(eng.rerun_incremental(sc2, d2, exchange_samples=samples,
                                           verify=True))
    np.testing.assert_array_equal(warms[1].assignment[dead2],
                                  warms[0].assignment[dead2])
    wload = np.bincount(warms[1].assignment[sc2.active_mask],
                        minlength=sc2.n_servers)
    assert (wload <= sc2.capacity).all()


def test_churn_scenario_cold_run_excludes_inactive():
    """A fresh engine on a churn scenario must park inactive devices with
    zero cost contribution: dropping them entirely from the scenario yields
    the same total cost."""
    sc = make_scenario(16, 4, seed=3, reach_m=300.0)
    sc1, d1 = perturb_scenario(sc, seed=6, move_frac=0.0, depart_frac=0.25)
    dead = np.flatnonzero(~sc1.active_mask)
    assert dead.size > 0
    res = FastAssociationEngine(sc1, kind="fast", seed=0, compact=True).run(
        "nearest", exchange_samples=0)
    member = np.zeros((sc.n_servers, sc.n_devices), dtype=bool)
    member[res.assignment, np.arange(sc.n_devices)] = True
    assert not member[:, dead].any() or (res.f[dead] == 0).all()
    base = FastAssociationEngine(sc, kind="fast", seed=0, compact=True).run(
        "nearest", exchange_samples=0)
    assert res.total_cost < base.total_cost  # fewer active devices
