"""FL runtime: Algorithm 1 semantics, HFEL vs FedAvg, masking."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_mnist_like
from repro.fl import FederatedTrainer, train_federated


def test_hfel_equals_fedavg_when_one_edge_iter_one_server():
    """With K=1, I=1, HFEL degenerates to FedAvg exactly."""
    ds = make_mnist_like(8, samples_total=800, seed=0)
    assign = np.zeros(8, dtype=np.int64)
    h1 = train_federated(ds, method="hfel", assignment=assign, n_servers=1,
                         rounds=3, local_iters=5, edge_iters=1, lr=0.05)
    h2 = train_federated(ds, method="fedavg", rounds=3, local_iters=5,
                         edge_iters=1, lr=0.05)
    np.testing.assert_allclose(h1.train_loss, h2.train_loss, rtol=1e-5)


@pytest.mark.slow
def test_training_improves_and_hfel_leads_under_noniid():
    ds = make_mnist_like(20, seed=1)
    h_hfel = train_federated(ds, method="hfel", n_servers=4, rounds=12,
                             local_iters=10, edge_iters=5, lr=0.05,
                             eval_every=2)
    h_fa = train_federated(ds, method="fedavg", rounds=12, local_iters=10,
                           edge_iters=5, lr=0.05, eval_every=2)
    assert h_hfel.test_acc[-1] > h_hfel.test_acc[0] + 0.1
    # paper Figs. 7-12: HFEL converges at least as fast (mid-training)
    mid = len(h_hfel.test_acc) // 2
    assert h_hfel.test_acc[mid] >= h_fa.test_acc[mid] - 0.01


def test_aggregation_weights_match_eq8():
    import jax
    ds = make_mnist_like(4, samples_total=400, seed=2)
    tr = FederatedTrainer(ds, lr=0.05)
    params = tr.client_params
    w = jnp.asarray(ds.client_sizes)
    # shift client c's params by +c; the weighted mean shift must follow
    # eq. (8): sum(w_c * c) / sum(w)
    tr.client_params = jax.tree.map(
        lambda p: p + jnp.arange(4, dtype=p.dtype).reshape(
            (4,) + (1,) * (p.ndim - 1)), params)
    tr.edge_aggregate(jnp.zeros(4, jnp.int32), 1)
    expect_shift = float((w * jnp.arange(4)).sum() / w.sum())
    got = jax.tree.leaves(tr.client_params)[0]
    base = jax.tree.leaves(params)[0]
    np.testing.assert_allclose(np.asarray(got[0] - base[0]).ravel()[0],
                               expect_shift, rtol=1e-5)


def test_client_mask_excludes_stragglers_from_aggregation():
    import jax
    ds = make_mnist_like(4, samples_total=400, seed=3)
    tr = FederatedTrainer(ds, lr=0.05)
    tr.client_params = jax.tree.map(
        lambda p: p.at[3].set(1e6), tr.client_params)
    tr.client_mask = jnp.asarray([True, True, True, False])
    tr.cloud_aggregate()
    assert float(jnp.max(jnp.abs(jax.tree.leaves(tr.client_params)[0]))) < 1e3
