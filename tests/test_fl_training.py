"""FL runtime: Algorithm 1 semantics, HFEL vs FedAvg, masking — plus the
aggregation invariants the live hot-swap (repro.fl.live) relies on, as
property tests over the hypothesis shim."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import make_mnist_like
from repro.fl import FederatedTrainer, train_federated


def test_hfel_equals_fedavg_when_one_edge_iter_one_server():
    """With K=1, I=1, HFEL degenerates to FedAvg exactly."""
    ds = make_mnist_like(8, samples_total=800, seed=0)
    assign = np.zeros(8, dtype=np.int64)
    h1 = train_federated(ds, method="hfel", assignment=assign, n_servers=1,
                         rounds=3, local_iters=5, edge_iters=1, lr=0.05)
    h2 = train_federated(ds, method="fedavg", rounds=3, local_iters=5,
                         edge_iters=1, lr=0.05)
    np.testing.assert_allclose(h1.train_loss, h2.train_loss, rtol=1e-5)


@pytest.mark.slow
def test_training_improves_and_hfel_leads_under_noniid():
    ds = make_mnist_like(20, seed=1)
    h_hfel = train_federated(ds, method="hfel", n_servers=4, rounds=12,
                             local_iters=10, edge_iters=5, lr=0.05,
                             eval_every=2)
    h_fa = train_federated(ds, method="fedavg", rounds=12, local_iters=10,
                           edge_iters=5, lr=0.05, eval_every=2)
    assert h_hfel.test_acc[-1] > h_hfel.test_acc[0] + 0.1
    # paper Figs. 7-12: HFEL converges at least as fast (mid-training)
    mid = len(h_hfel.test_acc) // 2
    assert h_hfel.test_acc[mid] >= h_fa.test_acc[mid] - 0.01


def test_aggregation_weights_match_eq8():
    import jax
    ds = make_mnist_like(4, samples_total=400, seed=2)
    tr = FederatedTrainer(ds, lr=0.05)
    params = tr.client_params
    w = jnp.asarray(ds.client_sizes)
    # shift client c's params by +c; the weighted mean shift must follow
    # eq. (8): sum(w_c * c) / sum(w)
    tr.client_params = jax.tree.map(
        lambda p: p + jnp.arange(4, dtype=p.dtype).reshape(
            (4,) + (1,) * (p.ndim - 1)), params)
    tr.edge_aggregate(jnp.zeros(4, jnp.int32), 1)
    expect_shift = float((w * jnp.arange(4)).sum() / w.sum())
    got = jax.tree.leaves(tr.client_params)[0]
    base = jax.tree.leaves(params)[0]
    np.testing.assert_allclose(np.asarray(got[0] - base[0]).ravel()[0],
                               expect_shift, rtol=1e-5)


def test_client_mask_excludes_stragglers_from_aggregation():
    ds = make_mnist_like(4, samples_total=400, seed=3)
    tr = FederatedTrainer(ds, lr=0.05)
    tr.client_params = jax.tree.map(
        lambda p: p.at[3].set(1e6), tr.client_params)
    tr.client_mask = jnp.asarray([True, True, True, False])
    tr.cloud_aggregate()
    assert float(jnp.max(jnp.abs(jax.tree.leaves(tr.client_params)[0]))) < 1e3


# -- helpers for the hot-swap contract tests ---------------------------------

_DS6 = make_mnist_like(6, samples_total=500, seed=4)


def _trainer(param_seed: int) -> FederatedTrainer:
    """A 6-client trainer whose per-client params were made distinct (one
    local step from a seeded shift), so aggregation actually mixes state."""
    tr = FederatedTrainer(_DS6, lr=0.05)
    rng = np.random.default_rng(param_seed)
    shift = jnp.asarray(rng.normal(0.0, 1.0, (6,)).astype(np.float32))
    tr.client_params = jax.tree.map(
        lambda p: p + shift.reshape((6,) + (1,) * (p.ndim - 1)), tr.client_params)
    return tr


def _global(tr):
    return jax.tree.leaves(tr.global_params())


def _weighted_mean(tr):
    w = np.asarray(tr._weights(), np.float64)
    leaf = np.asarray(jax.tree.leaves(tr.client_params)[0], np.float64)
    return (leaf * w.reshape((-1,) + (1,) * (leaf.ndim - 1))).sum(0) / w.sum()


# -- regression: the empty-group / all-masked bugs the live loop tripped -----

def test_edge_aggregate_empty_server_keeps_client_params():
    """A fully-departed server has no mean: its (masked) clients must keep
    their parameters, not receive the degenerate zero quotient that used to
    poison re-admission."""
    tr = _trainer(0)
    before = jax.tree.leaves(tr.client_params)[0].copy()
    tr.client_mask = jnp.asarray([True, True, True, True, False, False])
    assignment = jnp.asarray([0, 0, 0, 0, 1, 1])   # server 1 fully masked
    tr.edge_aggregate(assignment, 2)
    after = jax.tree.leaves(tr.client_params)[0]
    np.testing.assert_array_equal(np.asarray(after[4:]),
                                  np.asarray(before[4:]))
    # the live group still aggregated (its members now share params)
    np.testing.assert_allclose(np.asarray(after[0]), np.asarray(after[3]),
                               rtol=1e-6)


def test_cloud_aggregate_all_masked_keeps_params():
    tr = _trainer(1)
    before = jax.tree.leaves(tr.client_params)[0].copy()
    tr.client_mask = jnp.zeros(6, bool)
    tr.cloud_aggregate()
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(tr.client_params)[0]), np.asarray(before))


def test_readmit_clients_takes_edge_params_with_global_fallback():
    tr = _trainer(2)
    tr.client_mask = jnp.asarray([True, True, True, False, True, False])
    assignment = jnp.asarray([0, 0, 1, 1, 2, 2])
    # arrivals: client 3 joins server 1 (donor: client 2); client 5 joins
    # server 2 where the only other member (4) is... active, so it donates
    arrivals = jnp.asarray([False, False, False, True, False, True])
    tr.client_mask = tr.client_mask | arrivals
    tr.readmit_clients(arrivals, assignment, 3)
    leaf = jax.tree.leaves(tr.client_params)[0]
    np.testing.assert_allclose(np.asarray(leaf[3]), np.asarray(leaf[2]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(leaf[5]), np.asarray(leaf[4]),
                               rtol=1e-6)
    # empty target group -> global weighted mean over donors
    tr2 = _trainer(3)
    tr2.client_mask = jnp.asarray([True, True, True, True, True, False])
    arrivals2 = jnp.asarray([False] * 5 + [True])
    tr2.client_mask = tr2.client_mask | arrivals2
    tr_probe = _trainer(3)
    tr_probe.client_mask = jnp.asarray([True] * 5 + [False])
    donors_mean = _weighted_mean(tr_probe)
    tr2.readmit_clients(arrivals2, jnp.asarray([0, 0, 0, 1, 1, 2]), 3)
    got = np.asarray(jax.tree.leaves(tr2.client_params)[0][5])
    np.testing.assert_allclose(got, donors_mean, rtol=1e-5)


# -- property tests: the trainer-side contracts the hot-swap relies on -------

@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), n_servers=st.integers(1, 4))
def test_cloud_aggregate_invariant_to_assignment(seed, n_servers):
    """edge_aggregate(a) . cloud_aggregate yields the SAME global model for
    every assignment ``a`` (a weighted mean of group weighted means is the
    global weighted mean) — the invariant that makes swapping assignments
    between cloud aggregations safe."""
    rng = np.random.default_rng(seed)
    globals_ = []
    for _ in range(2):
        tr = _trainer(seed)
        assignment = jnp.asarray(rng.integers(0, n_servers, 6))
        tr.edge_aggregate(assignment, n_servers)
        tr.cloud_aggregate()
        globals_.append(_global(tr))
    for a, b in zip(*globals_):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), mask_bits=st.integers(1, 62))
def test_edge_aggregate_conserves_weighted_mean(seed, mask_bits):
    """The participating-weighted mean of the client fleet is unchanged by
    edge aggregation, for any participation mask and assignment (masked
    clients carry zero weight on both sides)."""
    rng = np.random.default_rng(seed)
    tr = _trainer(seed)
    mask = np.array([(mask_bits >> i) & 1 for i in range(6)], bool)
    if not mask.any():
        mask[0] = True
    tr.client_mask = jnp.asarray(mask)
    before = _weighted_mean(tr)
    tr.edge_aggregate(jnp.asarray(rng.integers(0, 3, 6)), 3)
    np.testing.assert_allclose(_weighted_mean(tr), before, rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), garbage=st.floats(1e3, 1e8))
def test_masked_client_never_influences_global_model(seed, garbage):
    """A departed (masked) client's parameters are inert: perturbing them
    arbitrarily changes NOTHING about the post-aggregation global model."""
    rng = np.random.default_rng(seed)
    assignment = jnp.asarray(rng.integers(0, 3, 6))
    mask = jnp.asarray([True, True, True, True, True, False])
    outs = []
    for junk in (garbage, -2.0 * garbage):
        tr = _trainer(seed)
        tr.client_mask = mask
        tr.client_params = jax.tree.map(
            lambda p: p.at[5].set(junk), tr.client_params)
        tr.edge_aggregate(assignment, 3)
        tr.cloud_aggregate()
        outs.append(_global(tr))
    for a, b in zip(*outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
