"""Sharded association sweep + fused golden-section kernel: parity of the
shard_map candidate refresh with the classic single-device engine (the PR's
bit-exactness contract), kernel-vs-reference parity in interpret mode, and
the memory-safe chunked distance construction.

Multi-device cases need ``XLA_FLAGS=--xla_force_host_platform_device_count``
(exported by ``scripts/tier1.sh``) and skip on a single-device run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_scenario
from repro.core import resource_allocation as ra
from repro.core.assoc_fast import FastAssociationEngine
from repro.core.scenario import (make_large_scenario, pairwise_dist,
                                 perturb_scenario)
from repro.kernels import ops, ref

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs XLA_FLAGS=--xla_force_host_platform_device_"
                      "count (scripts/tier1.sh exports it)")


def _batched_consts(g=8, r=16, seed=0):
    """(G, R) RAConstants batch + masks built by jittering one server's
    constants (same factor on f_min/f_max keeps the box ordered)."""
    from repro.core.cost_model import ra_constants
    sc = make_scenario(r, 2, seed=seed)
    c = ra_constants(sc.dev, sc.srv.bandwidth[0], sc.srv.noise[0], sc.lp)
    key = jax.random.key(seed + 13)
    scale = jax.random.uniform(key, (g, 1), minval=0.7, maxval=1.3)
    cg = jax.tree.map(
        lambda x: (jnp.broadcast_to(jnp.asarray(x), (g,))
                   if jnp.asarray(x).ndim == 0
                   else jnp.asarray(x)[None, :] * scale), c)
    masks = jax.random.uniform(jax.random.key(seed + 29), (g, r)) < 0.7
    masks = masks.at[:, 0].set(True)          # no empty groups
    masks = masks.at[0].set(jnp.arange(r) == 0)   # singleton group edge case
    return cg, masks


@pytest.mark.parametrize("profile", sorted(ra.SCREEN_PROFILES))
def test_golden_kernel_matches_fixed_point(profile):
    """Fused kernel vs the scalar solver vmapped, at every screening
    profile — the documented parity pin is rtol 2e-4 on cost (interpret
    mode is in practice bit-identical; real-TPU fusion need not be)."""
    iters = ra.SCREEN_PROFILES[profile]
    cg, masks = _batched_consts(seed=1)
    oracle = jax.vmap(
        lambda cc, m: ra.solve_fixed_point(cc, m, **iters))(cg, masks)
    sol = ra.solve_fixed_point_batched(cg, masks, backend="pallas", **iters)
    np.testing.assert_allclose(sol.cost, oracle.cost, rtol=2e-4)
    np.testing.assert_allclose(sol.deadline, oracle.deadline, rtol=2e-4)
    np.testing.assert_allclose(sol.f, oracle.f, rtol=2e-4)
    np.testing.assert_allclose(sol.beta, oracle.beta, rtol=2e-4, atol=1e-7)


def test_golden_kernel_matches_ref():
    """Kernel (interpret mode) vs the plain-jnp reference formulation —
    same math, same iteration counts, so the gap must be float noise."""
    cg, masks = _batched_consts(g=6, r=12, seed=2)
    f, beta, cost, dl = ops.golden_section_solve(
        cg.a, cg.b, cg.d, cg.e, cg.w, cg.f_min, cg.f_max, masks,
        n_golden=16, n_inner=6, n_bracket=24)
    f_r, beta_r, cost_r, dl_r = ref.golden_section_ref(
        cg.a, cg.b, cg.d, cg.e, cg.w, cg.f_min, cg.f_max, masks,
        n_golden=16, n_inner=6, n_bracket=24)
    np.testing.assert_allclose(cost, cost_r, rtol=1e-6)
    np.testing.assert_allclose(dl, dl_r, rtol=1e-6)
    np.testing.assert_allclose(f, f_r, rtol=1e-6)
    np.testing.assert_allclose(beta, beta_r, rtol=1e-6, atol=1e-9)


def test_golden_kernel_block_padding():
    """G not a multiple of block_g: padded rows must not leak into the
    first G outputs."""
    cg, masks = _batched_consts(g=5, r=10, seed=3)
    full = ops.golden_section_solve(
        cg.a, cg.b, cg.d, cg.e, cg.w, cg.f_min, cg.f_max, masks,
        n_golden=16, n_inner=6, n_bracket=24)
    blocked = ops.golden_section_solve(
        cg.a, cg.b, cg.d, cg.e, cg.w, cg.f_min, cg.f_max, masks,
        n_golden=16, n_inner=6, n_bracket=24, block_g=4)
    for x, y in zip(full, blocked):
        np.testing.assert_allclose(x, y, rtol=1e-6)


def test_batched_xla_matches_scalar_solver():
    """backend="xla" is the scalar solver vmapped — per-group results must
    match solving each group alone."""
    iters = ra.SCREEN_PROFILES["coarse"]
    cg, masks = _batched_consts(g=4, r=8, seed=4)
    sol = ra.solve_fixed_point_batched(cg, masks, backend="xla", **iters)
    for i in range(4):
        one = ra.solve_fixed_point(jax.tree.map(lambda x: x[i], cg),
                                   masks[i], **iters)
        np.testing.assert_allclose(sol.cost[i], one.cost, rtol=1e-6)
        np.testing.assert_allclose(sol.f[i], one.f, rtol=1e-6)


PARITY_CASES = [(14, 3, 0), (18, 4, 1)]


@pytest.mark.parametrize("compact", ["bucketed", True, False])
def test_sharded_one_device_identical(compact):
    """A 1-device mesh routes through shard_map + the collective merge; the
    stable point must stay bit-identical to the classic in-process sweep."""
    sc = make_scenario(14, 3, seed=0, reach_m=300.0)
    classic = FastAssociationEngine(sc, kind="fast", seed=0,
                                    compact=compact).run(
        "nearest", exchange_samples=0)
    sharded = FastAssociationEngine(sc, kind="fast", seed=0, compact=compact,
                                    shards=1).run(
        "nearest", exchange_samples=0)
    assert np.array_equal(classic.assignment, sharded.assignment)
    assert classic.n_adjustments == sharded.n_adjustments
    assert sharded.total_cost == pytest.approx(classic.total_cost, rel=1e-6)


@multi_device
@pytest.mark.parametrize("n,k,seed", PARITY_CASES)
def test_sharded_multi_device_identical(n, k, seed):
    """k-device mesh: psum'd cache init + all_gather winner merge must
    reproduce the sequential bucket fold's move sequence exactly."""
    sc = make_scenario(n, k, seed=seed, reach_m=300.0)
    classic = FastAssociationEngine(sc, kind="fast", seed=0,
                                    compact="bucketed").run(
        "nearest", exchange_samples=0)
    sharded = FastAssociationEngine(sc, kind="fast", seed=0,
                                    compact="bucketed", shards=N_DEV).run(
        "nearest", exchange_samples=0)
    assert np.array_equal(classic.assignment, sharded.assignment)
    assert classic.n_adjustments == sharded.n_adjustments


@pytest.mark.slow
@multi_device
def test_sharded_warm_rerun_parity():
    """rerun_incremental on a sharded engine: warm stable point must match
    the classic engine's warm rerun AND pass its own verify gate (cold
    rebuild from the same repaired assignment)."""
    sc = make_large_scenario(120, 6, seed=5)
    classic = FastAssociationEngine(sc, kind="fast", seed=0,
                                    profile="coarse", compact="bucketed")
    classic.run("nearest", exchange_samples=0)
    sharded = FastAssociationEngine(sc, kind="fast", seed=0,
                                    profile="coarse", compact="bucketed",
                                    shards=N_DEV)
    sharded.run("nearest", exchange_samples=0)
    sc2, delta = perturb_scenario(sc, seed=6, drift_m=60.0, move_frac=0.05,
                                  flip_frac=0.02, depart_frac=0.02)
    warm_c = classic.rerun_incremental(sc2, delta, exchange_samples=0)
    warm_s = sharded.rerun_incremental(sc2, delta, exchange_samples=0,
                                       verify=True)
    assert np.array_equal(warm_c.assignment, warm_s.assignment)
    assert warm_c.n_adjustments == warm_s.n_adjustments


@pytest.mark.slow
def test_pallas_backend_engine_matches_xla():
    """ra_backend="pallas" swaps the refresh solver for the fused kernel;
    the stable point must agree within the kernel's documented tolerance
    (interpret mode lands bit-identical)."""
    sc = make_scenario(14, 3, seed=0, reach_m=300.0)
    xla = FastAssociationEngine(sc, kind="fast", seed=0,
                                compact="bucketed").run(
        "nearest", exchange_samples=0)
    pal = FastAssociationEngine(sc, kind="fast", seed=0, compact="bucketed",
                                ra_backend="pallas").run(
        "nearest", exchange_samples=0)
    assert np.array_equal(xla.assignment, pal.assignment)
    assert pal.total_cost == pytest.approx(xla.total_cost, rel=2e-4)


# The PR-10 contract matrix: sharded stable points AND per-move traces are
# bit-identical to the single-device engine across every sweep space ×
# shard count × exchange setting. The (16, 4, seed=1) geometry is the one
# the exchange tests pin (transfers alone stall short of the exchange-on
# stable point, so the escape path genuinely fires).
EXCHANGE_MATRIX = [(c, p, s)
                   for c in ("bucketed", True, False)
                   for p in (1, 3, 4)
                   for s in (0, 64)]


@pytest.mark.parametrize(
    "compact,shards,samples", EXCHANGE_MATRIX,
    ids=[f"{'dense' if c is False else 'flat' if c is True else c}"
         f"-p{p}-ex{s}" for c, p, s in EXCHANGE_MATRIX])
def test_sharded_exchange_parity_matrix(compact, shards, samples):
    """Distributed sampled exchanges (PR 10): the replicated pair proposal +
    chunk-partitioned pricing + all_gather (delta, sample-order) winner fold
    must reproduce the single-device exchange sequence bit-for-bit — same
    assignment, same move count, same per-move cost trace."""
    if shards > N_DEV:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count")
    sc = make_scenario(16, 4, seed=1, reach_m=300.0)
    classic = FastAssociationEngine(sc, kind="fast", seed=0,
                                    compact=compact).run(
        "nearest", exchange_samples=samples)
    sharded = FastAssociationEngine(sc, kind="fast", seed=0, compact=compact,
                                    shards=shards).run(
        "nearest", exchange_samples=samples)
    assert np.array_equal(classic.assignment, sharded.assignment)
    assert classic.n_adjustments == sharded.n_adjustments
    assert classic.cost_trace == sharded.cost_trace  # per-move, bitwise
    if samples:
        # the geometry guarantees the exchange branch fires: with exchanges
        # the descent moves strictly beyond the transfers-only stable point
        no_ex = FastAssociationEngine(sc, kind="fast", seed=0,
                                      compact=compact).run(
            "nearest", exchange_samples=0)
        assert classic.n_adjustments > no_ex.n_adjustments
        assert classic.total_cost < no_ex.total_cost * (1 - 1e-5)


@pytest.mark.slow
@multi_device
def test_sharded_warm_rerun_parity_with_exchanges():
    """The warm path carries the lifted restriction too: a sharded
    rerun_incremental with exchange_samples>0 matches the classic warm rerun
    bit-identically AND passes its own verify gate (cold rebuild from the
    same repaired assignment, exchanges on)."""
    sc = make_large_scenario(120, 6, seed=5)
    classic = FastAssociationEngine(sc, kind="fast", seed=0,
                                    profile="coarse", compact="bucketed")
    classic.run("nearest", exchange_samples=64)
    sharded = FastAssociationEngine(sc, kind="fast", seed=0,
                                    profile="coarse", compact="bucketed",
                                    shards=N_DEV)
    sharded.run("nearest", exchange_samples=64)
    sc2, delta = perturb_scenario(sc, seed=6, drift_m=60.0, move_frac=0.05,
                                  flip_frac=0.02, depart_frac=0.02)
    warm_c = classic.rerun_incremental(sc2, delta, exchange_samples=64)
    warm_s = sharded.rerun_incremental(sc2, delta, exchange_samples=64,
                                       verify=True)
    assert np.array_equal(warm_c.assignment, warm_s.assignment)
    assert warm_c.n_adjustments == warm_s.n_adjustments
    assert warm_c.cost_trace == warm_s.cost_trace


def test_sharded_constructor_validation():
    sc = make_scenario(14, 3, seed=0)
    with pytest.raises(ValueError):
        FastAssociationEngine(sc, kind="fast", seed=0, shards=0)
    with pytest.raises(ValueError):
        FastAssociationEngine(sc, kind="fast", seed=0, shards=N_DEV + 1)
    with pytest.raises(ValueError):
        FastAssociationEngine(sc, kind="fast", seed=0, ra_backend="mosaic")
    with pytest.raises(ValueError):
        FastAssociationEngine(sc, kind="exact", seed=0, ra_backend="pallas")


def test_pairwise_dist_chunked_bitwise():
    """Chunked distance computation must be bit-identical to the dense
    broadcast it replaces, including chunk sizes that straddle N."""
    rng = np.random.default_rng(0)
    srv = rng.uniform(0, 1000, (7, 2))
    dev = rng.uniform(0, 1000, (103, 2))
    dense = np.linalg.norm(srv[:, None, :] - dev[None, :, :], axis=-1)
    for chunk in (1, 13, 103, 200):
        assert np.array_equal(pairwise_dist(srv, dev, chunk=chunk), dense)
    assert pairwise_dist(srv, dev[:0]).shape == (7, 0)


@pytest.mark.slow
@multi_device
def test_sharded_n20000_converges():
    """N=20k/K=200 sharded convergence smoke: the regime cap lift + chunked
    construction + sharded sweep exist for. Coarse/loose-tol so the run
    stays minutes, not hours; asserts genuine stability (no move-cap
    exit)."""
    sc = make_large_scenario(20_000, 200, seed=0, spread_m=60.0)
    eng = FastAssociationEngine(sc, kind="fast", seed=0, profile="coarse",
                                rel_tol=1e-2, compact="bucketed",
                                shards=N_DEV)
    eng.run("nearest", max_moves=4000, exchange_samples=0, finalize=False)
    assert eng.last_moves < 4000
    assign = eng.stable_assignment
    avail = np.asarray(sc.avail)
    active = sc.active_mask
    assert assign is not None
    assert avail[assign[active], np.flatnonzero(active)].all()
