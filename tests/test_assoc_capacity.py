"""Capacity-constrained association: per-edge ``max_devices`` through the
whole stack — cap generation (`cap_slack`), the fast kernel's headroom gate,
reference-engine parity under binding caps, capacitated repair in
``rerun_incremental``, and the guarded zero-feasible errors that replaced
the silent server-0 fallbacks."""

import dataclasses

import numpy as np
import pytest

from repro.core import (AssociationEngine, NoFeasibleServerError,
                        diff_scenarios, greedy_admission, make_large_scenario,
                        make_scenario, nearest_feasible, parked_slots,
                        perturb_scenario, repair_assignment)
from repro.core.assoc_fast import FastAssociationEngine
from repro.core.edge_association import initial_assignment

CHURN = dict(drift_m=60.0, move_frac=0.2, flip_frac=0.1,
             depart_frac=0.15, arrive_frac=0.3)


def _load(assignment: np.ndarray, active: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(assignment[active], minlength=k)


# ---------------------------------------------------------------------------
# cap generation
# ---------------------------------------------------------------------------

def test_cap_slack_none_keeps_capacity_none():
    assert make_scenario(12, 3, seed=0).capacity is None
    assert make_large_scenario(12, 3, seed=0).capacity is None


def test_cap_generation_deterministic_and_draw_compatible():
    """Deriving caps consumes no rng draws: every other scenario field is
    bit-identical with and without ``cap_slack``."""
    a = make_large_scenario(24, 4, seed=3)
    b = make_large_scenario(24, 4, seed=3, cap_slack=1.2)
    c = make_large_scenario(24, 4, seed=3, cap_slack=1.2)
    np.testing.assert_array_equal(a.dist, b.dist)
    np.testing.assert_array_equal(a.avail, b.avail)
    np.testing.assert_array_equal(a.dev_xy, b.dev_xy)
    assert a.capacity is None
    np.testing.assert_array_equal(b.capacity, c.capacity)
    # sized from the nearest-server load profile, never below 1
    nearest = np.bincount(np.argmin(b.dist, axis=0), minlength=b.n_servers)
    np.testing.assert_array_equal(
        b.capacity, np.maximum(1, np.ceil(1.2 * nearest)).astype(np.int64))
    assert (b.capacity >= 1).all()


def test_cap_slack_must_be_positive():
    with pytest.raises(ValueError, match="cap_slack"):
        make_scenario(8, 2, seed=0, cap_slack=0.0)


def test_perturb_carries_caps_and_diff_rejects_mismatch():
    sc = make_large_scenario(16, 3, seed=0, cap_slack=1.2)
    sc2, _ = perturb_scenario(sc, seed=1, **CHURN)
    np.testing.assert_array_equal(sc2.capacity, sc.capacity)
    stripped = dataclasses.replace(sc2, max_devices=None)
    with pytest.raises(ValueError, match="capacit"):
        diff_scenarios(sc, stripped)


# ---------------------------------------------------------------------------
# guarded helpers
# ---------------------------------------------------------------------------

def test_nearest_feasible_raises_on_needed_empty_column():
    dist = np.array([[1.0, 5.0], [2.0, 9.0]])
    feasible = np.array([[True, False], [True, False]])
    with pytest.raises(NoFeasibleServerError) as ei:
        nearest_feasible(dist, feasible)
    np.testing.assert_array_equal(ei.value.devices, [1])
    # exempting the dead column via `need` succeeds
    out = nearest_feasible(dist, feasible, need=np.array([True, False]))
    assert out[0] == 0


def test_greedy_admission_sequential_load_accounting():
    # one server with cap 1, two devices both nearest to it: the second
    # must spill to the farther server, the third (unreachable) stays -1
    # and consumes no load.
    dist = np.array([[1.0, 2.0, 3.0],
                     [10.0, 11.0, 12.0]])
    feasible = np.array([[True, True, False],
                         [True, True, False]])
    load = np.zeros(2, dtype=np.int64)
    cap = np.array([1, 1])
    placed = greedy_admission(dist, feasible, load, cap,
                              np.array([0, 1, 2]))
    np.testing.assert_array_equal(placed, [0, 1, -1])
    np.testing.assert_array_equal(load, [1, 1])
    # unplaced device consumed no headroom: re-running just it with a
    # fresh reachable row succeeds
    placed2 = greedy_admission(dist, np.ones_like(feasible),
                               np.zeros(2, np.int64), cap, np.array([2]))
    np.testing.assert_array_equal(placed2, [0])


def test_initial_assignment_raises_instead_of_server0():
    sc = make_scenario(6, 3, seed=2)
    avail = sc.avail.copy()
    avail[:, 4] = False  # device 4 can reach nothing
    rng = np.random.default_rng(0)
    with pytest.raises(NoFeasibleServerError) as ei:
        initial_assignment(sc, avail, rng, "nearest")
    assert 4 in ei.value.devices
    with pytest.raises(NoFeasibleServerError):
        initial_assignment(sc, avail, np.random.default_rng(0), "random")


def test_initial_assignment_capacitated_respects_caps():
    sc = make_large_scenario(20, 4, seed=1, cap_slack=1.0)
    rng = np.random.default_rng(0)
    out = initial_assignment(sc, sc.eff_avail, rng, "nearest")
    act = sc.active_mask
    assert (_load(out, act, sc.n_servers) <= sc.capacity).all()
    out_r = initial_assignment(sc, sc.eff_avail,
                               np.random.default_rng(0), "random")
    assert (_load(out_r, act, sc.n_servers) <= sc.capacity).all()


# ---------------------------------------------------------------------------
# stable points under binding caps
# ---------------------------------------------------------------------------

def test_fast_engine_never_exceeds_binding_caps():
    sc = make_large_scenario(24, 4, seed=0, cap_slack=1.0)
    res = FastAssociationEngine(sc, kind="fast", seed=0).run(
        "nearest", exchange_samples=0)
    load = _load(res.assignment, sc.active_mask, sc.n_servers)
    assert (load <= sc.capacity).all()
    # the caps genuinely bind: the uncapacitated engine on the same
    # geometry concentrates load beyond at least one cap
    base = dataclasses.replace(sc, max_devices=None)
    res0 = FastAssociationEngine(base, kind="fast", seed=0).run(
        "nearest", exchange_samples=0)
    load0 = _load(res0.assignment, sc.active_mask, sc.n_servers)
    assert (load0 > sc.capacity).any()
    # and capping costs something: constrained optimum is no better
    assert res.total_cost >= res0.total_cost - 1e-9


def test_non_binding_caps_bit_identical_to_uncapped():
    """caps = N never gate a move (an inbound transfer needs a donor group
    elsewhere), so the capacitated engine must replay the uncapacitated
    descent bit-for-bit."""
    sc = make_scenario(18, 4, seed=5)
    capped = dataclasses.replace(
        sc, max_devices=np.full(sc.n_servers, sc.n_devices, np.int64))
    a = FastAssociationEngine(sc, kind="fast", seed=0).run("nearest")
    b = FastAssociationEngine(capped, kind="fast", seed=0).run("nearest")
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert a.total_cost == b.total_cost


@pytest.mark.parametrize("compact", [False, True])
def test_fast_vs_reference_move_for_move_with_binding_caps(compact):
    sc = make_large_scenario(20, 4, seed=2, cap_slack=1.0)
    ref = AssociationEngine(sc, kind="fast", seed=0).run_batched(
        "nearest", exchange_samples=0)
    fast = FastAssociationEngine(sc, kind="fast", seed=0,
                                 compact=compact).run(
        "nearest", exchange_samples=0)
    np.testing.assert_array_equal(ref.assignment, fast.assignment)
    assert abs(ref.total_cost - fast.total_cost) <= 1e-4 * fast.total_cost
    load = _load(fast.assignment, sc.active_mask, sc.n_servers)
    assert (load <= sc.capacity).all()


def test_reference_engine_run_respects_caps():
    sc = make_large_scenario(18, 3, seed=4, cap_slack=1.0)
    res = AssociationEngine(sc, kind="fast", seed=0).run(
        exchange_samples=0)
    load = _load(res.assignment, sc.active_mask, sc.n_servers)
    assert (load <= sc.capacity).all()


# ---------------------------------------------------------------------------
# churn: capacitated repair + warm/cold parity
# ---------------------------------------------------------------------------

def test_rerun_incremental_warm_cold_parity_with_caps():
    sc = make_large_scenario(24, 4, seed=0, cap_slack=1.3)
    eng = FastAssociationEngine(sc, kind="fast", seed=0)
    eng.run("nearest", exchange_samples=0)
    cur = sc
    for step in range(3):
        nxt, delta = perturb_scenario(cur, seed=10 + step, **CHURN)
        res = eng.rerun_incremental(nxt, delta, verify=True)
        load = _load(res.assignment, nxt.active_mask, nxt.n_servers)
        assert (load <= nxt.capacity).all()
        cur = nxt


def test_rerun_incremental_rejects_changed_caps():
    sc = make_large_scenario(16, 3, seed=0, cap_slack=1.3)
    eng = FastAssociationEngine(sc, kind="fast", seed=0)
    eng.run("nearest", exchange_samples=0)
    sc2, delta = perturb_scenario(sc, seed=1, **CHURN)
    sc2 = dataclasses.replace(sc2, max_devices=sc.capacity + 1)
    with pytest.raises(ValueError, match="max_devices|capacit"):
        eng.rerun_incremental(sc2, delta)


def test_repair_raises_when_last_reachable_server_churns_away():
    """Regression for the silent server-0 fallback: a displaced device with
    zero effectively-reachable servers must raise with its index, not park
    on server 0."""
    sc = make_scenario(8, 3, seed=1)
    prev = nearest_feasible(sc.dist, sc.avail)
    avail = sc.avail.copy()
    avail[:, 3] = False  # churn device 3's last reachable server away
    sc2 = dataclasses.replace(sc, avail=avail)
    with pytest.raises(NoFeasibleServerError) as ei:
        repair_assignment(sc2, prev, np.ones(8, bool))
    assert 3 in ei.value.devices


def test_capacitated_repair_readmits_arrivals_within_caps():
    sc = make_large_scenario(20, 4, seed=6, cap_slack=1.3)
    eng = FastAssociationEngine(sc, kind="fast", seed=0)
    res = eng.run("nearest", exchange_samples=0)
    sc2, _ = perturb_scenario(sc, seed=3, **CHURN)
    assign, departed, arrived, displaced = repair_assignment(
        sc2, res.assignment, sc.active_mask)
    load = _load(assign, sc2.active_mask, sc2.n_servers)
    assert (load <= sc2.capacity).all()
    # keepers kept their slots
    keep = sc2.active_mask & sc.active_mask & ~displaced
    np.testing.assert_array_equal(assign[keep], res.assignment[keep])
